//! Epoch-based membership reconfiguration: surviving replicas agree to
//! evict a suspected member into a new epoch so that (a) the executed-
//! frontier GC stops waiting for the dead member's reports
//! ([`GCTrack::evict`](super::GCTrack) — memory stays bounded under
//! faults) and (b) messages from evicted members are fenced off at
//! dispatch.
//!
//! The agreement is deliberately lightweight — it is a *view change*, not
//! a consensus instance: every survivor that suspects a member broadcasts
//! a vote `MEpoch { epoch: current+1, evicted }` for the exact next-epoch
//! eviction set, re-broadcasting each tick until installed. Receiving a
//! vote for the next epoch endorses it (the receiver adopts the suspicion
//! and starts voting for the same set), so votes converge on the union of
//! all suspicions. A process installs the new epoch once a **majority of
//! the original group** voted for the exact `(epoch, set)` pair; because
//! eviction sets are cumulative (each proposal is `evicted ∪ suspected`),
//! any two installed histories are prefix-compatible — the checker's
//! `EpochDivergence` oracle verifies exactly this.
//!
//! Votes for epochs at or below the current one are stale and ignored;
//! the `Config::epoch_fence_off` test knob disables that guard and pushes
//! stale installs straight into the history, which makes the history
//! non-monotonic — the seeded violation for the checker's
//! `EpochRegression` oracle.

use super::base::Process;
use crate::core::ProcessId;
use crate::protocol::Action;
use std::collections::{BTreeSet, HashMap};

/// Per-process epoch state: the installed history, the suspicion set, and
/// the vote tally for pending proposals.
#[derive(Clone, Debug)]
pub struct EpochManager {
    id: ProcessId,
    /// The original (epoch-0) shard group; majorities are counted against
    /// its size so eviction can never be decided by a minority island.
    group: Vec<ProcessId>,
    /// TEST KNOB — accept stale installs (see `Config::epoch_fence_off`).
    fence_off: bool,
    /// Installed `(epoch, evicted members)` pairs, oldest first. Starts
    /// at `(0, [])`; eviction sets are cumulative and sorted.
    history: Vec<(u64, Vec<ProcessId>)>,
    /// Members this process currently suspects (never itself).
    suspected: BTreeSet<ProcessId>,
    /// Votes per exact `(epoch, eviction set)` pair.
    votes: HashMap<(u64, Vec<ProcessId>), BTreeSet<ProcessId>>,
    /// Members evicted by the currently installed epoch.
    evicted: BTreeSet<ProcessId>,
}

impl EpochManager {
    /// Manager for process `id` whose epoch-0 group is `group`.
    pub fn new(id: ProcessId, group: Vec<ProcessId>, fence_off: bool) -> Self {
        EpochManager {
            id,
            group,
            fence_off,
            history: vec![(0, Vec::new())],
            suspected: BTreeSet::new(),
            votes: HashMap::new(),
            evicted: BTreeSet::new(),
        }
    }

    /// The currently installed epoch number.
    pub fn epoch(&self) -> u64 {
        self.history.last().expect("history starts at epoch 0").0
    }

    /// The full installed history (for `Protocol::epoch_view`).
    pub fn history(&self) -> &[(u64, Vec<ProcessId>)] {
        &self.history
    }

    /// Is `p` evicted under the current epoch? Dispatch fencing: drop
    /// messages whose sender this returns `true` for.
    pub fn rejects(&self, p: ProcessId) -> bool {
        self.evicted.contains(&p)
    }

    /// Failure-detector input: start suspecting `p`. Self-suspicion and
    /// already-evicted members are ignored.
    pub fn suspect(&mut self, p: ProcessId) {
        if p != self.id && !self.evicted.contains(&p) {
            self.suspected.insert(p);
        }
    }

    /// The proposal this process should currently vote for, if any: the
    /// next epoch with the cumulative eviction set `evicted ∪ suspected`.
    /// `None` once every suspicion is covered by the installed epoch.
    pub fn proposal(&self) -> Option<(u64, Vec<ProcessId>)> {
        if self.suspected.is_subset(&self.evicted) {
            return None;
        }
        let set: Vec<ProcessId> =
            self.evicted.union(&self.suspected).copied().collect();
        // BTreeSet union iterates in order, so `set` is sorted — exact-match
        // vote counting and deterministic wire bytes both rely on this.
        Some((self.epoch() + 1, set))
    }

    /// Record `from`'s vote for evicting `set` into `epoch`. Returns the
    /// newly evicted members when this vote installs the epoch (the
    /// caller must then evict them from GC and count the eviction).
    pub fn vote(
        &mut self,
        from: ProcessId,
        epoch: u64,
        set: Vec<ProcessId>,
    ) -> Option<Vec<ProcessId>> {
        if set.contains(&self.id) {
            // Never endorse our own eviction; if a majority installs it
            // anyway, their fencing handles us.
            return None;
        }
        if epoch <= self.epoch() {
            if self.fence_off {
                // TEST KNOB: a stale install re-enters an old epoch —
                // the history stops being monotonic and the checker's
                // EpochRegression oracle must flag it.
                self.history.push((epoch, set));
            }
            return None;
        }
        // Endorse: adopt the proposal's suspicions so our own next vote
        // converges on the same set.
        for &p in &set {
            self.suspect(p);
        }
        let voters = self.votes.entry((epoch, set.clone())).or_default();
        voters.insert(from);
        if voters.len() < self.group.len() / 2 + 1 {
            return None;
        }
        let delta: Vec<ProcessId> =
            set.iter().copied().filter(|p| !self.evicted.contains(p)).collect();
        self.evicted = set.iter().copied().collect();
        self.history.push((epoch, set));
        self.votes.retain(|(e, _), _| *e > epoch);
        Some(delta)
    }
}

/// Protocols that reconfigure through [`EpochManager`]. Implementors
/// provide the manager and the protocol-specific reaction to an eviction
/// (GC exclusion, counter bump); the vote ingest and the periodic
/// proposal re-broadcast live here once, shared by all families.
pub trait EpochProcess: Process {
    /// The protocol's [`EpochManager`] instance.
    fn epoch_mgr(&mut self) -> &mut EpochManager;

    /// `member` was just evicted by a newly installed epoch: exclude it
    /// from the GC frontier and drop any per-member protocol state.
    fn on_evicted(&mut self, member: ProcessId);

    /// Ingest a peer's epoch vote (the `MEpoch` handler). Installs the
    /// epoch and applies evictions when the vote completes a majority;
    /// also casts our own (possibly newly adopted) vote back out so
    /// agreement completes without waiting for the next tick.
    fn handle_epoch(
        &mut self,
        from: ProcessId,
        epoch: u64,
        evicted: Vec<ProcessId>,
        wrap: impl Fn(u64, Vec<ProcessId>) -> Self::Msg,
        out: &mut Vec<Action<Self::Msg>>,
    ) {
        if !self.base().config.epochs_enabled {
            return;
        }
        if let Some(delta) = self.epoch_mgr().vote(from, epoch, evicted) {
            for member in delta {
                self.on_evicted(member);
            }
            return;
        }
        // Not installed yet: make sure our own endorsement is tallied and
        // visible to peers (ours may be the completing majority vote).
        self.epoch_tick(&wrap, out);
    }

    /// One periodic reconfiguration step: while a proposal is pending,
    /// tally our own vote and re-broadcast it to the group (re-sending
    /// every tick rides out lossy links — and guarantees stale arrivals
    /// after the install, which the fence must reject).
    fn epoch_tick(
        &mut self,
        wrap: impl Fn(u64, Vec<ProcessId>) -> Self::Msg,
        out: &mut Vec<Action<Self::Msg>>,
    ) {
        if !self.base().config.epochs_enabled {
            return;
        }
        let me = self.base().id;
        let Some((epoch, set)) = self.epoch_mgr().proposal() else {
            return;
        };
        if let Some(delta) = self.epoch_mgr().vote(me, epoch, set.clone()) {
            for member in delta {
                self.on_evicted(member);
            }
            return;
        }
        for p in self.base().group_procs.clone() {
            if p != me {
                out.push(Action::send(p, wrap(epoch, set.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(id: u32) -> EpochManager {
        EpochManager::new(ProcessId(id), (0..5).map(ProcessId).collect(), false)
    }

    #[test]
    fn majority_installs_and_reports_delta() {
        let mut m = mgr(0);
        m.suspect(ProcessId(4));
        let (e, set) = m.proposal().expect("suspicion pending");
        assert_eq!((e, set.clone()), (1, vec![ProcessId(4)]));
        assert!(m.vote(ProcessId(0), e, set.clone()).is_none(), "1 of 3 needed");
        assert!(m.vote(ProcessId(1), e, set.clone()).is_none(), "2 of 3 needed");
        let delta = m.vote(ProcessId(2), e, set.clone()).expect("majority reached");
        assert_eq!(delta, vec![ProcessId(4)]);
        assert_eq!(m.epoch(), 1);
        assert!(m.rejects(ProcessId(4)));
        assert!(m.proposal().is_none(), "suspicion covered by the install");
    }

    #[test]
    fn duplicate_votes_do_not_count_twice() {
        let mut m = mgr(0);
        m.suspect(ProcessId(4));
        let (e, set) = m.proposal().unwrap();
        for _ in 0..10 {
            assert!(m.vote(ProcessId(1), e, set.clone()).is_none());
        }
        assert_eq!(m.epoch(), 0, "one voter however often it repeats");
    }

    #[test]
    fn stale_votes_are_fenced() {
        let mut m = mgr(0);
        m.suspect(ProcessId(4));
        let (e, set) = m.proposal().unwrap();
        for p in 0..3 {
            m.vote(ProcessId(p), e, set.clone());
        }
        assert_eq!(m.epoch(), 1);
        let before = m.history().to_vec();
        assert!(m.vote(ProcessId(3), e, set).is_none(), "stale epoch");
        assert_eq!(m.history(), &before[..], "stale install rejected");
    }

    #[test]
    fn fence_off_knob_regresses_the_history() {
        let mut m = EpochManager::new(
            ProcessId(0),
            (0..5).map(ProcessId).collect(),
            true,
        );
        m.suspect(ProcessId(4));
        let (e, set) = m.proposal().unwrap();
        for p in 0..3 {
            m.vote(ProcessId(p), e, set.clone());
        }
        assert_eq!(m.epoch(), 1);
        m.vote(ProcessId(3), e, set);
        let epochs: Vec<u64> = m.history().iter().map(|&(e, _)| e).collect();
        assert_eq!(epochs, vec![0, 1, 1], "stale install entered the history");
    }

    #[test]
    fn votes_adopt_suspicions_and_sets_stay_cumulative() {
        let mut m = mgr(0);
        // We suspect nobody, but a peer proposes evicting P4.
        m.vote(ProcessId(1), 1, vec![ProcessId(4)]);
        let (e, set) = m.proposal().expect("adopted the suspicion");
        assert_eq!((e, set), (1, vec![ProcessId(4)]));
        // Install epoch 1, then suspect P3: the next set is cumulative.
        let (e, set) = m.proposal().unwrap();
        for p in [0u32, 2, 3] {
            m.vote(ProcessId(p), e, set.clone());
        }
        m.suspect(ProcessId(3));
        let (e, set) = m.proposal().unwrap();
        assert_eq!((e, set), (2, vec![ProcessId(3), ProcessId(4)]));
    }

    #[test]
    fn never_endorses_own_eviction() {
        let mut m = mgr(4);
        for p in 0..5 {
            assert!(m.vote(ProcessId(p), 1, vec![ProcessId(4)]).is_none());
        }
        assert_eq!(m.epoch(), 0);
        assert!(m.proposal().is_none(), "did not adopt self-suspicion");
    }

    #[test]
    fn split_proposals_converge_via_adoption() {
        // A votes {4}, B votes {3, 4}: after hearing B, A's proposal is
        // the union and exact-match counting can reach a majority on it.
        let mut m = mgr(0);
        m.suspect(ProcessId(4));
        m.vote(ProcessId(0), 1, vec![ProcessId(4)]);
        m.vote(ProcessId(1), 1, vec![ProcessId(3), ProcessId(4)]);
        let (e, set) = m.proposal().unwrap();
        assert_eq!((e, set.clone()), (1, vec![ProcessId(3), ProcessId(4)]));
        m.vote(ProcessId(0), e, set.clone());
        let delta = m.vote(ProcessId(2), e, set).expect("3 exact votes");
        assert_eq!(delta, vec![ProcessId(3), ProcessId(4)]);
    }
}
