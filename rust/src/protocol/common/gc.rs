//! Group-wide garbage collection of executed-command state (the fantoch
//! `GCTrack` idea): each process records the commands it executed as
//! per-origin contiguous frontiers, periodically exchanges those frontiers
//! with its shard group (`MGarbageCollect`), and prunes per-command state
//! once *every* group member has executed a command — at that point nobody
//! can need its payload, timestamps, or dependencies again.
//!
//! Frontiers are contiguous (`SourceTracker` watermark), so sequence
//! numbers are assumed 1-based (as `DotGen` mints them). Under partial
//! replication a group executes only the subset of an origin's commands
//! that touch its keys, so foreign-shard gaps stall that origin's frontier
//! and GC degrades to a no-op — safe, but unbounded; per-group sequence
//! spaces are a ROADMAP item.
//!
//! Under worker sharding (`protocol::common::shard`) each worker slot
//! owns an interleaved stride of every origin's sequence space (worker
//! `w` of `N` mints `w+1, w+1+N, …`), so a per-worker tracker built with
//! [`GCTrack::strided`] folds the stride into a dense 1-based *index*
//! space: frontiers stay contiguous per worker, worker `w` instances
//! exchange frontiers only with their peers' worker-`w` instances (the
//! router tags messages), and pruning maps indices back to dots via
//! [`GCTrack::dot_at`]. [`GCTrack::new`] is the identity stride.

use super::base::Process;
use super::stability::SourceTracker;
use crate::core::{Dot, ProcessId, Stride};
use crate::protocol::Action;
use std::collections::HashMap;

/// Executed-command frontier tracking and the group-wide prune decision.
///
/// Records local executions as per-origin contiguous frontiers, folds in
/// the frontiers peers report via `MGarbageCollect`, and yields the dot
/// ranges every group member executed — safe to prune everywhere.
#[derive(Clone, Debug)]
pub struct GCTrack {
    id: ProcessId,
    group: Vec<ProcessId>,
    /// Worker stride: this tracker covers the slot's sequence subset.
    stride: Stride,
    /// Dots executed locally, per origin (in stride-index space).
    executed: HashMap<ProcessId, SourceTracker>,
    /// Latest contiguous frontier reported by each group member, per origin.
    reported: HashMap<ProcessId, HashMap<ProcessId, u64>>,
    /// Per-origin index up to which state was already pruned.
    pruned: HashMap<ProcessId, u64>,
}

impl GCTrack {
    /// Tracker for process `id` whose shard group is `group` (identity
    /// stride: sequence space == index space).
    pub fn new(id: ProcessId, group: Vec<ProcessId>) -> Self {
        Self::strided(id, group, 0, 1)
    }

    /// Tracker for worker slot `worker` of `workers` at process `id`:
    /// covers the dots of that slot's [`Stride`] and keeps their frontier
    /// dense despite the interleaving.
    pub fn strided(id: ProcessId, group: Vec<ProcessId>, worker: usize, workers: usize) -> Self {
        GCTrack {
            id,
            group,
            stride: Stride::new(worker, workers),
            executed: HashMap::new(),
            reported: HashMap::new(),
            pruned: HashMap::new(),
        }
    }

    /// The dot at stride index `index` (1-based) of `origin` — the inverse
    /// of the mapping `record_executed` applies. Pruning loops iterate
    /// `safe_to_prune` index ranges through this.
    pub fn dot_at(&self, origin: ProcessId, index: u64) -> Dot {
        Dot::new(origin, self.stride.seq_at(index))
    }

    /// Record a locally executed command.
    pub fn record_executed(&mut self, dot: Dot) {
        match self.stride.index_of(dot.seq) {
            Some(i) => self.executed.entry(dot.origin).or_default().add(i),
            None => debug_assert!(false, "dot {dot} outside worker stride"),
        }
    }

    /// Was `dot` executed locally? Used to guard against resurrecting
    /// pruned state from stale messages and promise re-broadcasts.
    /// Dots of other worker slots report `false`.
    pub fn was_executed(&self, dot: Dot) -> bool {
        self.stride
            .index_of(dot.seq)
            .is_some_and(|i| self.executed.get(&dot.origin).is_some_and(|t| t.contains(i)))
    }

    /// Our per-origin contiguous executed frontier — the `MGarbageCollect`
    /// payload. Sorted for deterministic wire bytes.
    pub fn snapshot(&self) -> Vec<(ProcessId, u64)> {
        let mut v: Vec<(ProcessId, u64)> = self
            .executed
            .iter()
            .map(|(&origin, t)| (origin, t.highest_contiguous()))
            .filter(|&(_, wm)| wm > 0)
            .collect();
        v.sort_unstable_by_key(|&(origin, _)| origin);
        v
    }

    /// Evict `member` from the group: the prune decision stops waiting
    /// for its frontier reports, so a crashed member no longer freezes
    /// the GC frontier (epoch reconfiguration calls this on install).
    pub fn evict(&mut self, member: ProcessId) {
        self.group.retain(|&m| m != member);
        self.reported.remove(&member);
    }

    /// Incorporate a member's frontier report (frontiers only advance).
    pub fn update_from(&mut self, member: ProcessId, frontiers: &[(ProcessId, u64)]) {
        let slot = self.reported.entry(member).or_default();
        for &(origin, wm) in frontiers {
            let e = slot.entry(origin).or_insert(0);
            if wm > *e {
                *e = wm;
            }
        }
    }

    /// Newly safe-to-prune ranges: per origin, the stride indices
    /// `lo..=hi` (map to dots via [`GCTrack::dot_at`]; with the identity
    /// stride, indices *are* sequence numbers) that every group member
    /// (us included) has executed and that were not pruned yet. Advances
    /// the internal pruned marker.
    pub fn safe_to_prune(&mut self) -> Vec<(ProcessId, u64, u64)> {
        let mut out = Vec::new();
        for (&origin, tracker) in &self.executed {
            let mut frontier = tracker.highest_contiguous();
            for member in &self.group {
                if *member == self.id {
                    continue;
                }
                let reported = self
                    .reported
                    .get(member)
                    .and_then(|m| m.get(&origin))
                    .copied()
                    .unwrap_or(0);
                frontier = frontier.min(reported);
            }
            let done = self.pruned.entry(origin).or_insert(0);
            if frontier > *done {
                out.push((origin, *done + 1, frontier));
                *done = frontier;
            }
        }
        out.sort_unstable_by_key(|&(origin, ..)| origin);
        out
    }
}

/// Protocols that garbage-collect through [`GCTrack`]. Implementors
/// provide the tracker and the protocol-specific pruning of newly safe
/// dots; the periodic frontier exchange and the `MGarbageCollect` ingest
/// live here once, shared by all protocol families.
pub trait GcProcess: Process {
    /// The protocol's [`GCTrack`] instance.
    fn gc_track(&mut self) -> &mut GCTrack;

    /// Drop protocol state for every dot [`GCTrack::safe_to_prune`]
    /// reports (info records, stalled messages, conflict tables, ...).
    fn prune_executed(&mut self);

    /// Ingest a peer's executed-frontier report and prune.
    fn handle_garbage_collect(&mut self, from: ProcessId, executed: &[(ProcessId, u64)]) {
        self.gc_track().update_from(from, executed);
        self.prune_executed();
    }

    /// One periodic GC step: on every `gc_interval_ticks`-th tick,
    /// broadcast our executed frontier to the group (wrapped into the
    /// protocol's message type by `wrap`) and prune locally.
    fn gc_tick(
        &mut self,
        ticks: u64,
        wrap: impl Fn(Vec<(ProcessId, u64)>) -> Self::Msg,
        out: &mut Vec<Action<Self::Msg>>,
    ) {
        let every = self.base().config.gc_interval_ticks;
        if every == 0 || ticks % every != 0 {
            return;
        }
        let snap = self.gc_track().snapshot();
        if snap.is_empty() {
            return;
        }
        let me = self.base().id;
        for p in self.base().group_procs.clone() {
            if p != me {
                out.push(Action::send(p, wrap(snap.clone())));
            }
        }
        self.prune_executed();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dot(p: u32, s: u64) -> Dot {
        Dot::new(ProcessId(p), s)
    }

    fn track() -> GCTrack {
        GCTrack::new(ProcessId(0), (0..3).map(ProcessId).collect())
    }

    #[test]
    fn nothing_safe_until_every_member_reports() {
        let mut gc = track();
        gc.record_executed(dot(5, 1));
        gc.record_executed(dot(5, 2));
        assert!(gc.safe_to_prune().is_empty(), "peers have not reported");
        gc.update_from(ProcessId(1), &[(ProcessId(5), 2)]);
        assert!(gc.safe_to_prune().is_empty(), "P2 has not reported");
        gc.update_from(ProcessId(2), &[(ProcessId(5), 1)]);
        assert_eq!(gc.safe_to_prune(), vec![(ProcessId(5), 1, 1)]);
        // Only the delta comes back next time.
        gc.update_from(ProcessId(2), &[(ProcessId(5), 2)]);
        assert_eq!(gc.safe_to_prune(), vec![(ProcessId(5), 2, 2)]);
        assert!(gc.safe_to_prune().is_empty(), "no double pruning");
    }

    #[test]
    fn frontier_is_bounded_by_own_execution() {
        let mut gc = track();
        gc.record_executed(dot(5, 1));
        gc.update_from(ProcessId(1), &[(ProcessId(5), 50)]);
        gc.update_from(ProcessId(2), &[(ProcessId(5), 50)]);
        assert_eq!(gc.safe_to_prune(), vec![(ProcessId(5), 1, 1)]);
    }

    #[test]
    fn gaps_stall_the_frontier() {
        let mut gc = track();
        gc.record_executed(dot(5, 1));
        gc.record_executed(dot(5, 3)); // gap at 2
        gc.update_from(ProcessId(1), &[(ProcessId(5), 3)]);
        gc.update_from(ProcessId(2), &[(ProcessId(5), 3)]);
        assert_eq!(gc.safe_to_prune(), vec![(ProcessId(5), 1, 1)]);
        gc.record_executed(dot(5, 2));
        assert_eq!(gc.safe_to_prune(), vec![(ProcessId(5), 2, 3)]);
    }

    #[test]
    fn was_executed_survives_pruning() {
        let mut gc = track();
        gc.record_executed(dot(5, 1));
        gc.update_from(ProcessId(1), &[(ProcessId(5), 1)]);
        gc.update_from(ProcessId(2), &[(ProcessId(5), 1)]);
        let _ = gc.safe_to_prune();
        assert!(gc.was_executed(dot(5, 1)));
        assert!(!gc.was_executed(dot(5, 2)));
    }

    #[test]
    fn evicting_a_silent_member_unfreezes_the_frontier() {
        let mut gc = track();
        gc.record_executed(dot(5, 1));
        gc.update_from(ProcessId(1), &[(ProcessId(5), 1)]);
        // P2 crashed before reporting: nothing is ever safe...
        assert!(gc.safe_to_prune().is_empty(), "frozen on the dead member");
        // ...until the epoch layer evicts it.
        gc.evict(ProcessId(2));
        assert_eq!(gc.safe_to_prune(), vec![(ProcessId(5), 1, 1)]);
    }

    #[test]
    fn strided_tracker_keeps_dense_frontiers() {
        // Worker 1 of 4: owns seqs 2, 6, 10, ... Executing them in order
        // advances the frontier without gaps; foreign-stride dots are
        // invisible; index ranges map back to the right dots.
        let mut gc =
            GCTrack::strided(ProcessId(0), (0..3).map(ProcessId).collect(), 1, 4);
        let origin = ProcessId(5);
        for seq in [2u64, 6, 10] {
            gc.record_executed(Dot::new(origin, seq));
        }
        assert_eq!(gc.snapshot(), vec![(origin, 3)], "dense despite the stride");
        assert!(gc.was_executed(Dot::new(origin, 6)));
        assert!(!gc.was_executed(Dot::new(origin, 3)), "foreign stride is not ours");
        gc.update_from(ProcessId(1), &[(origin, 3)]);
        gc.update_from(ProcessId(2), &[(origin, 2)]);
        assert_eq!(gc.safe_to_prune(), vec![(origin, 1, 2)]);
        assert_eq!(gc.dot_at(origin, 1), Dot::new(origin, 2));
        assert_eq!(gc.dot_at(origin, 2), Dot::new(origin, 6));
        assert_eq!(gc.dot_at(origin, 3), Dot::new(origin, 10));
    }

    #[test]
    fn identity_stride_indices_are_sequence_numbers() {
        let gc = track();
        for seq in 1..10 {
            assert_eq!(gc.dot_at(ProcessId(7), seq), Dot::new(ProcessId(7), seq));
        }
    }

    #[test]
    fn snapshot_reports_contiguous_frontiers_sorted() {
        let mut gc = track();
        gc.record_executed(dot(7, 1));
        gc.record_executed(dot(2, 1));
        gc.record_executed(dot(2, 2));
        gc.record_executed(dot(2, 9)); // gap: not part of the frontier
        assert_eq!(gc.snapshot(), vec![(ProcessId(2), 2), (ProcessId(7), 1)]);
    }
}
