//! Per-dot retransmission pacing with capped exponential backoff.
//!
//! `Config::retry_interval_ticks` alone re-drives *every* in-flight dot
//! on every N-th tick: after a long partition every stalled dot fires
//! on the same tick, so the heal instant sees a retransmit storm
//! proportional to the outage length. [`RetryPacer`] spreads that out:
//! each key backs off individually — first retry one base interval
//! after it is first seen, then doubling up to
//! `Config::retry_backoff_cap_ticks` — so steady-state stragglers are
//! still re-driven promptly while long-stalled dots retry at the cap
//! cadence instead of every opportunity.
//!
//! With `cap == 0` the pacer is pass-through (every key is always due),
//! which keeps the legacy fixed-cadence behaviour — and every seeded
//! run — bit-identical; the protocol's own `ticks % base` gate then
//! provides the cadence exactly as before this module existed.

use std::collections::BTreeMap;

/// Per-key retransmission schedule: first due `base` ticks after a key
/// is first consulted, then doubling intervals capped at `cap`.
///
/// Keys are whatever the protocol retries on (dots here); the pacer
/// never retries anything itself — the owner asks [`RetryPacer::due`]
/// on its retry ticks and must [`RetryPacer::retain`] the live key set
/// periodically so completed commands do not leak schedule entries.
#[derive(Debug, Clone)]
pub struct RetryPacer<K: Ord + Copy> {
    base: u64,
    cap: u64,
    /// key → (next due tick, completed attempts).
    sched: BTreeMap<K, (u64, u32)>,
}

impl<K: Ord + Copy> RetryPacer<K> {
    /// A pacer with retry base interval `base` ticks and backoff cap
    /// `cap` ticks. `cap == 0` disables backoff (pass-through).
    pub fn new(base: u64, cap: u64) -> Self {
        Self { base, cap: if cap == 0 { 0 } else { cap.max(base) }, sched: BTreeMap::new() }
    }

    /// Whether backoff is active (`cap != 0`). With backoff off the
    /// owner keeps its legacy global `ticks % base` cadence gate.
    pub fn backoff_enabled(&self) -> bool {
        self.cap != 0
    }

    /// Is `key` due for a retransmit at `tick`? First call for a key
    /// schedules it `base` ticks out and answers no; each yes advances
    /// the key's next due point by `min(base · 2^attempts, cap)`.
    /// Pass-through (always yes, no state) when backoff is disabled.
    pub fn due(&mut self, key: K, tick: u64) -> bool {
        if self.cap == 0 {
            return true;
        }
        match self.sched.get_mut(&key) {
            None => {
                self.sched.insert(key, (tick.saturating_add(self.base), 0));
                false
            }
            Some((next, attempts)) => {
                if tick < *next {
                    return false;
                }
                *attempts += 1;
                let interval =
                    self.base.saturating_mul(1u64 << (*attempts).min(32)).min(self.cap);
                *next = tick.saturating_add(interval.max(1));
                true
            }
        }
    }

    /// Drop schedule entries whose key no longer needs retries (the
    /// owner passes its live in-flight set).
    pub fn retain(&mut self, mut live: impl FnMut(&K) -> bool) {
        self.sched.retain(|k, _| live(k));
    }

    /// Forget one key (e.g. on commit, so any later commit-stage
    /// retries of the same dot start from a fresh schedule).
    pub fn clear(&mut self, key: &K) {
        self.sched.remove(key);
    }

    /// Number of scheduled keys (tests / introspection).
    pub fn len(&self) -> usize {
        self.sched.len()
    }

    /// Whether no keys are scheduled.
    pub fn is_empty(&self) -> bool {
        self.sched.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The documented schedule, pinned: base 4, cap 32 fires a key at
    /// offsets +4, +12, +28, +60, +92, … after first sight (intervals
    /// 4, 8, 16, 32, 32 — doubling until the cap).
    #[test]
    fn backoff_schedule_is_pinned() {
        let mut p = RetryPacer::new(4, 32);
        assert!(p.backoff_enabled());
        // First sight at tick 0 schedules, does not fire.
        assert!(!p.due(7u64, 0));
        let mut fired = Vec::new();
        for tick in 1..=100 {
            if p.due(7u64, tick) {
                fired.push(tick);
            }
        }
        assert_eq!(fired, vec![4, 12, 28, 60, 92]);
    }

    #[test]
    fn pass_through_when_cap_zero() {
        let mut p = RetryPacer::new(4, 0);
        assert!(!p.backoff_enabled());
        for tick in 0..10 {
            assert!(p.due(1u64, tick), "cap=0 must always be due");
        }
        assert!(p.is_empty(), "pass-through keeps no state");
    }

    #[test]
    fn keys_back_off_independently_and_retain_prunes() {
        let mut p = RetryPacer::new(2, 8);
        assert!(!p.due(1u64, 0));
        assert!(!p.due(2u64, 5));
        assert!(p.due(1u64, 2), "key 1 due at its own offset");
        assert!(!p.due(2u64, 6), "key 2 not due on key 1's schedule");
        assert!(p.due(2u64, 7));
        assert_eq!(p.len(), 2);
        p.retain(|k| *k == 2);
        assert_eq!(p.len(), 1);
        // Cleared keys restart from a fresh first-sight schedule.
        p.clear(&2u64);
        assert!(!p.due(2u64, 100));
        assert!(p.due(2u64, 102));
    }

    /// A storm of keys first seen together still fires together on the
    /// first retry, but their later retries stay bounded by the cap —
    /// the property the satellite exists for is that a key retried n
    /// times has sent only O(log(outage)) retransmits, not outage/base.
    #[test]
    fn long_outage_costs_logarithmic_retries() {
        let mut p = RetryPacer::new(4, 64);
        let mut count = 0;
        p.due(9u64, 0);
        for tick in 1..=1000 {
            if p.due(9u64, tick) {
                count += 1;
            }
        }
        // Fixed cadence would fire 250 times; backoff fires at
        // +4 +12 +28 +60 +124 then every 64: well under 25.
        assert!(count < 25, "got {count} retries over 1000 ticks");
    }
}
