//! Per-key worker sharding of protocol state (the fantoch parallel-worker
//! idea, adapted to this crate's shared-nothing state machines).
//!
//! Tempo's timestamping is per-key by construction (paper §2, §6.3), so a
//! replica's protocol state partitions cleanly by key. [`Sharded`] splits
//! one replica into `Config::workers` *worker slots*, each a complete,
//! unmodified inner protocol instance over the keys that hash to it:
//!
//! ```text
//!                 ┌────────────────────── replica p ──────────────────────┐
//!   submit(cmd) ──┤ route: worker_of_key(keys[0])                         │
//!                 │   ┌─────────┐  ┌─────────┐        ┌─────────┐         │
//!   handle(m)  ───┤──▶│ inner 0 │  │ inner 1 │  ...   │ inner N-1│        │
//!  (by msg.worker)│   └────┬────┘  └────┬────┘        └────┬────┘         │
//!                 │        └─ actions merged in worker order ─┘           │
//!                 └── Send{to, msg} lifted to Send{to, Routed{w, msg}} ───┘
//! ```
//!
//! **Sharding invariants.** The key→worker map ([`worker_of_key`]) is a
//! pure global hash, identical at every replica, so worker `w` of all
//! replicas forms one complete protocol instance over its key subset —
//! quorums, promise stores, GC exchanges and recovery all stay within a
//! slot. Each slot mints dots on its own interleaved sequence stride
//! (`DotGen::strided`), so a dot names its owning worker
//! ([`worker_of_dot`]) and acks/commits/recovery messages route without
//! rehashing keys; outbound messages additionally carry the sender
//! slot in a [`Routed`] envelope, which routes *every* message kind
//! (promise broadcasts and GC frontier exchanges included) with one rule.
//!
//! **What is and is not shared.** Nothing is shared between slots: each
//! inner instance owns its clocks, promise stores, command info, batcher,
//! GC tracker and dot generator. The runtimes own what is genuinely
//! per-replica: the executor/KV store (commands of different slots never
//! share a key, so their state-machine effects commute) and the
//! client-session plumbing.
//!
//! **Determinism.** `tick` drives the slots round-robin in worker order
//! and concatenates their actions; `handle` touches exactly one slot.
//! Under the simulator's canonical intra-timestamp event ordering
//! (`sim::EventKey`) this makes a sharded run a pure function of the
//! delivered-message multiset — `rust/tests/workers.rs` proves
//! `workers=1 == workers=4` execution equivalence for Tempo, EPaxos,
//! Atlas, Janus* and Caesar the way `rust/tests/batching.rs` proved
//! batched == unbatched (Caesar's globally-coupled proposal clock makes
//! its byte-exact claim hold on co-hashing key sets; under multi-slot
//! traffic it is safe but legitimately re-times — see the test).
//!
//! **Limits.** A command must live entirely inside one slot: every key it
//! accesses has to hash to the same worker (single-key commands — the
//! paper's microbenchmark shape — always do). Commands whose keys span
//! slots would need the cross-partition commit/stability machinery *within*
//! a replica; that is the ROADMAP follow-up, and [`Sharded::submit`]
//! rejects such commands loudly rather than corrupting per-key order.
//! FPaxos can run under the router (each slot is an independent leader
//! log; PSMR still holds), but its single total-order log is *not*
//! execution-equivalent to a monolithic run by design.

use super::super::{Action, Footprint, Protocol};
use crate::core::{Command, Config, Dot, Key, ProcessId, Stride};
use crate::metrics::Counters;

/// Worker slot owning `key` among `workers` slots: a global pure hash
/// (SplitMix64 finalizer — decorrelated from [`crate::core::key_to_shard`]
/// so worker partitions cut across shard partitions evenly).
pub fn worker_of_key(key: Key, workers: usize) -> usize {
    if workers <= 1 {
        return 0;
    }
    let mut z = key.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z % workers as u64) as usize
}

/// Worker slot that minted `dot`: slots allocate interleaved sequence
/// strides (`DotGen::strided`), so ownership is carried by the dot itself
/// and survives recovery (any process can compute it without the command
/// payload).
pub fn worker_of_dot(dot: Dot, workers: usize) -> usize {
    Stride::owner_of(dot.seq, workers)
}

/// Worker slot of `cmd`, if all its keys co-locate; `Err((a, b))` names
/// two slots the key set spans otherwise.
pub fn worker_of_cmd(cmd: &Command, workers: usize) -> Result<usize, (usize, usize)> {
    let w = cmd.keys.first().map_or(0, |&k| worker_of_key(k, workers));
    for &k in cmd.keys.iter() {
        let wk = worker_of_key(k, workers);
        if wk != w {
            return Err((w, wk));
        }
    }
    Ok(w)
}

/// Envelope around an inner protocol message naming the worker slot it
/// belongs to. Sender slot `w` talks only to receiver slot `w`, so the
/// tag routes every message kind uniformly (wire form: docs/WIRE.md
/// tag 19).
#[derive(Clone, Debug)]
pub struct Routed<M> {
    /// Worker slot index of the sending (and therefore receiving) instance.
    pub worker: u32,
    /// The inner protocol message.
    pub msg: M,
}

/// A replica sharded into `Config::workers` shared-nothing inner protocol
/// instances; implements [`Protocol`] itself, so the simulator, the TCP
/// runtime, the checker and the benches run it unchanged.
pub struct Sharded<P: Protocol> {
    slots: Vec<P>,
}

impl<P: Protocol> Sharded<P> {
    fn lift(worker: u32, actions: Vec<Action<P::Message>>) -> Vec<Action<Routed<P::Message>>> {
        actions
            .into_iter()
            .map(|a| match a {
                Action::Send { to, msg } => Action::Send { to, msg: Routed { worker, msg } },
                Action::SendShared { to, msg } => {
                    Action::SendShared { to, msg: Routed { worker, msg } }
                }
                // Already-encoded bodies carry their envelope in the
                // bytes; nothing to lift.
                Action::SendBytes { to, body } => Action::SendBytes { to, body },
                Action::Submitted { dot } => Action::Submitted { dot },
                Action::Execute { dot, cmd, ts } => Action::Execute { dot, cmd, ts },
                Action::ExecuteRead { cmd, covered, slack } => {
                    Action::ExecuteRead { cmd, covered, slack }
                }
                Action::Reply { rid, response, ts } => Action::Reply { rid, response, ts },
                Action::Committed { dot, fast } => Action::Committed { dot, fast },
                Action::RecoveryStarted { dot } => Action::RecoveryStarted { dot },
            })
            .collect()
    }

    /// Number of worker slots.
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// The inner instance of worker slot `w` (tests/diagnostics).
    pub fn slot(&self, w: usize) -> &P {
        &self.slots[w]
    }
}

impl<P: Protocol> Protocol for Sharded<P> {
    type Message = Routed<P::Message>;

    fn new(id: ProcessId, config: Config) -> Self {
        let n = config.workers.max(1);
        // The wire envelope names the slot in one byte; a silent u8
        // truncation would misroute traffic, so refuse loudly here too
        // (for configs built without `with_workers`).
        assert!(n <= 256, "workers must be <= 256 (u8 slot on the wire)");
        let slots = (0..n)
            .map(|w| {
                let mut c = config.clone();
                c.workers = n;
                c.worker = w;
                P::new(id, c)
            })
            .collect();
        Sharded { slots }
    }

    fn name() -> &'static str {
        P::name()
    }

    /// Route the command to the worker slot owning its keys. All keys
    /// must co-locate (see the module docs); a spanning key set is a
    /// routing error, rejected loudly.
    fn submit(&mut self, cmd: Command, time_us: u64) -> Vec<Action<Self::Message>> {
        let n = self.slots.len();
        let w = match worker_of_cmd(&cmd, n) {
            Ok(w) => w,
            Err((a, b)) => panic!(
                "command {:?} spans worker slots {a} and {b} (workers={n}): \
                 cross-worker commands need the in-replica multi-partition \
                 protocol (ROADMAP); route them with workers=1",
                cmd.rid
            ),
        };
        Self::lift(w as u32, self.slots[w].submit(cmd, time_us))
    }

    /// Route the read to the worker slot owning its keys — the stash and
    /// the stability frontier that releases it both live inside that
    /// slot's inner instance, so the `(worker slot, timestamp)` parking
    /// key of the design falls out of the routing. Spanning key sets are
    /// rejected loudly, exactly like [`Sharded::submit`].
    fn submit_read(
        &mut self,
        cmd: Command,
        floor: u64,
        time_us: u64,
    ) -> Vec<Action<Self::Message>> {
        let n = self.slots.len();
        let w = match worker_of_cmd(&cmd, n) {
            Ok(w) => w,
            Err((a, b)) => panic!(
                "read {:?} spans worker slots {a} and {b} (workers={n}): \
                 cross-worker commands need the in-replica multi-partition \
                 protocol (ROADMAP); route them with workers=1",
                cmd.rid
            ),
        };
        Self::lift(w as u32, self.slots[w].submit_read(cmd, floor, time_us))
    }

    /// Route by the envelope tag: sender slot `w` talks to our slot `w`.
    /// An out-of-range tag (hostile wire input) is dropped.
    fn handle(
        &mut self,
        from: ProcessId,
        msg: Self::Message,
        time_us: u64,
    ) -> Vec<Action<Self::Message>> {
        let w = msg.worker as usize;
        if w >= self.slots.len() {
            return Vec::new();
        }
        Self::lift(msg.worker, self.slots[w].handle(from, msg.msg, time_us))
    }

    /// Drive every slot, round-robin in worker order, and concatenate
    /// their actions (the deterministic merge the equivalence proof
    /// relies on).
    fn tick(&mut self, time_us: u64) -> Vec<Action<Self::Message>> {
        let mut out = Vec::new();
        for (w, slot) in self.slots.iter_mut().enumerate() {
            out.extend(Self::lift(w as u32, slot.tick(time_us)));
        }
        out
    }

    fn crash(&mut self) {
        for s in &mut self.slots {
            s.crash();
        }
    }

    fn suspect(&mut self, p: ProcessId) {
        for s in &mut self.slots {
            s.suspect(p);
        }
    }

    fn note_restart(&mut self, dot_floor: u64) {
        for s in &mut self.slots {
            s.note_restart(dot_floor);
        }
    }

    fn counters(&self) -> Counters {
        let mut c = Counters::default();
        for s in &self.slots {
            c.merge(&s.counters());
        }
        c
    }

    /// The envelope costs two wire bytes on top of the inner message —
    /// the tag-19 byte plus the worker-slot byte (`net::wire::encode_routed`).
    fn msg_size(msg: &Self::Message) -> u64 {
        2 + P::msg_size(&msg.msg)
    }

    fn footprint(&self) -> Footprint {
        let mut f = Footprint::default();
        for s in &self.slots {
            let sf = s.footprint();
            f.infos += sf.infos;
            f.keys += sf.keys;
            f.stalled += sf.stalled;
            f.queued += sf.queued;
            f.fragments += sf.fragments;
        }
        f
    }

    /// Every slot receives the same suspicion inputs and runs the same
    /// deterministic vote, so slot 0's view speaks for the replica (the
    /// checker's cross-process divergence oracle still audits all
    /// replicas against each other).
    fn epoch_view(&self) -> Vec<(u64, Vec<ProcessId>)> {
        self.slots[0].epoch_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Op, Rid};

    #[test]
    fn worker_of_key_is_total_stable_and_balanced() {
        for workers in 1..=8 {
            let mut counts = vec![0u32; workers];
            for key in 0..8_000u64 {
                let w = worker_of_key(key, workers);
                assert!(w < workers);
                assert_eq!(w, worker_of_key(key, workers), "must be stable");
                counts[w] += 1;
            }
            let fair = 8_000 / workers as u32;
            for &c in &counts {
                assert!(
                    c > fair / 2 && c < fair * 2,
                    "unbalanced at {workers} workers: {counts:?}"
                );
            }
        }
    }

    #[test]
    fn worker_of_dot_matches_the_strided_generator() {
        use crate::core::DotGen;
        for workers in 1..=5 {
            for w in 0..workers {
                let mut g = DotGen::strided(ProcessId(3), w, workers);
                for _ in 0..20 {
                    assert_eq!(worker_of_dot(g.next(), workers), w);
                }
            }
        }
    }

    #[test]
    fn worker_of_cmd_detects_spanning_key_sets() {
        let workers = 4;
        // Find two keys in different slots and two in the same slot.
        let k0 = (0..).find(|&k| worker_of_key(k, workers) == 0).unwrap();
        let k0b = (k0 + 1..).find(|&k| worker_of_key(k, workers) == 0).unwrap();
        let k1 = (0..).find(|&k| worker_of_key(k, workers) == 1).unwrap();
        let same = Command::new(Rid::new(ClientId(1), 1), vec![k0, k0b], Op::Put, 0);
        assert_eq!(worker_of_cmd(&same, workers), Ok(0));
        let span = Command::new(Rid::new(ClientId(1), 2), vec![k0, k1], Op::Put, 0);
        assert!(worker_of_cmd(&span, workers).is_err());
    }
}
