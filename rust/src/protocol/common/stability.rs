//! The shared stability kernel (paper §3.2, Theorem 1): contiguous
//! per-source frontiers and the majority order-statistic watermark.
//!
//! Three consumers share this module so the computation exists exactly
//! once:
//! - `protocol::tempo::promises::PromiseStore` tracks promise frontiers per
//!   source process and maintains the majority watermark *incrementally*
//!   through [`QuorumFrontier`] (updated on add/commit deltas instead of
//!   re-scanning every tracker on each dirty pass);
//! - `protocol::common::gc::GCTrack` tracks executed-command frontiers per
//!   origin with the same [`SourceTracker`];
//! - `runtime::stability` (the batched kernel reference) computes the same
//!   order statistic over a promise bitmap via [`majority_watermark`].

use crate::core::{Dot, ProcessId, Stride};
use std::collections::{BTreeSet, HashMap};

/// Set of known values (promises, executed sequence numbers...) from a
/// single source, tracked as a contiguous watermark plus a sparse set of
/// out-of-order values — `highest_contiguous` is then O(1).
#[derive(Clone, Debug, Default)]
pub struct SourceTracker {
    /// All values `1..=watermark` are present.
    watermark: u64,
    /// Values above the watermark, not yet contiguous.
    above: BTreeSet<u64>,
}

impl SourceTracker {
    /// `highest_contiguous_promise(j)` of Algorithm 2.
    #[inline]
    pub fn highest_contiguous(&self) -> u64 {
        self.watermark
    }

    /// Is `u` present (1-based)?
    #[inline]
    pub fn contains(&self, u: u64) -> bool {
        u >= 1 && (u <= self.watermark || self.above.contains(&u))
    }

    /// Add a single value.
    pub fn add(&mut self, u: u64) {
        if u <= self.watermark {
            return;
        }
        if u == self.watermark + 1 {
            self.watermark = u;
            self.drain_contiguous();
        } else {
            self.above.insert(u);
        }
    }

    /// Add the inclusive range `lo..=hi` (no-op if `lo > hi`).
    pub fn add_range(&mut self, lo: u64, hi: u64) {
        if lo > hi {
            return;
        }
        if lo <= self.watermark + 1 {
            if hi > self.watermark {
                self.watermark = hi;
                self.drain_contiguous();
            }
        } else {
            self.above.extend(lo..=hi);
        }
    }

    fn drain_contiguous(&mut self) {
        while self.above.remove(&(self.watermark + 1)) {
            self.watermark += 1;
        }
        // Values at or below the watermark are redundant; drop them.
        if let Some(&min) = self.above.iter().next() {
            if min <= self.watermark {
                self.above = self.above.split_off(&(self.watermark + 1));
            }
        }
    }

    /// Number of values buffered out of order (diagnostics).
    pub fn pending(&self) -> usize {
        self.above.len()
    }
}

/// Largest `s` such that at least `majority` of `frontiers` are `>= s`:
/// the `majority`-th largest frontier (Algorithm 2 line 50, generalized to
/// an arbitrary majority size). Sorts `frontiers` in place.
pub fn majority_watermark(frontiers: &mut [u64], majority: usize) -> u64 {
    debug_assert!(majority >= 1 && majority <= frontiers.len());
    frontiers.sort_unstable();
    frontiers[frontiers.len() - majority]
}

/// Incrementally maintained majority watermark over a fixed source set.
///
/// The seed recomputed every key's stable watermark by collecting and
/// sorting all per-source frontiers on each dirty pass; here the watermark
/// is updated only when a source's frontier actually advances (`update` is
/// O(r log r) with r <= 9 in practice and allocation-free after
/// construction) and `watermark` is an O(1) read.
#[derive(Clone, Debug, Default)]
pub struct QuorumFrontier {
    sources: Vec<(ProcessId, u64)>,
    majority: usize,
    watermark: u64,
    scratch: Vec<u64>,
}

impl QuorumFrontier {
    /// Frontier over `processes` with the given `majority` threshold.
    pub fn new(processes: &[ProcessId], majority: usize) -> Self {
        assert!(majority >= 1 && majority <= processes.len());
        QuorumFrontier {
            sources: processes.iter().map(|&p| (p, 0)).collect(),
            majority,
            watermark: 0,
            scratch: Vec::with_capacity(processes.len()),
        }
    }

    /// An unconfigured frontier ignores updates and reports watermark 0.
    pub fn is_configured(&self) -> bool {
        !self.sources.is_empty()
    }

    /// Record that `source`'s contiguous frontier advanced to `frontier`.
    /// Returns true when the majority watermark advanced.
    pub fn update(&mut self, source: ProcessId, frontier: u64) -> bool {
        let entry = match self.sources.iter_mut().find(|(p, _)| *p == source) {
            Some(e) => e,
            None => return false, // unknown source (or unconfigured)
        };
        if frontier <= entry.1 {
            return false;
        }
        entry.1 = frontier;
        self.scratch.clear();
        self.scratch.extend(self.sources.iter().map(|&(_, v)| v));
        let w = majority_watermark(&mut self.scratch, self.majority);
        if w > self.watermark {
            self.watermark = w;
            true
        } else {
            false
        }
    }

    /// The current majority watermark, O(1).
    #[inline]
    pub fn watermark(&self) -> u64 {
        self.watermark
    }
}

/// Set of executed [`Dot`]s, stored per-origin as a contiguous frontier
/// plus sparse overflow — bounded in steady state, unlike a `HashSet` of
/// every dot ever executed. Tolerates 0-based sequence numbers (tests use
/// them) by offsetting into the 1-based [`SourceTracker`] space.
///
/// Under worker sharding a per-worker instance sees only the interleaved
/// sequence stride its worker slot owns; [`ExecutedSet::strided`] folds
/// that stride into a dense index space so the frontier still advances
/// contiguously (the default is the identity stride).
#[derive(Clone, Debug)]
pub struct ExecutedSet {
    per_origin: HashMap<ProcessId, SourceTracker>,
    stride: Stride,
}

impl Default for ExecutedSet {
    fn default() -> Self {
        Self::strided(0, 1)
    }
}

impl ExecutedSet {
    /// Set covering worker slot `worker` of `workers` (the dots of that
    /// slot's [`Stride`]).
    pub fn strided(worker: usize, workers: usize) -> Self {
        ExecutedSet { per_origin: HashMap::new(), stride: Stride::new(worker, workers) }
    }

    /// Dense 1-based index of `dot` within the stride, or `None` for dots
    /// of other worker slots. The identity stride keeps the historical +1
    /// offset so 0-based test sequences keep working; real strides cover
    /// the 1-based sequences `DotGen::strided` mints.
    fn index_of(&self, dot: Dot) -> Option<u64> {
        if self.stride.is_identity() {
            return Some(dot.seq.saturating_add(1));
        }
        self.stride.index_of(dot.seq)
    }

    /// Record `dot` as executed.
    pub fn insert(&mut self, dot: Dot) {
        match self.index_of(dot) {
            Some(i) => self.per_origin.entry(dot.origin).or_default().add(i),
            None => debug_assert!(false, "dot {dot} outside worker stride"),
        }
    }

    /// Was `dot` recorded as executed? Dots of other worker slots report
    /// `false`.
    pub fn contains(&self, dot: Dot) -> bool {
        self.index_of(dot)
            .is_some_and(|i| self.per_origin.get(&dot.origin).is_some_and(|t| t.contains(i)))
    }

    /// Out-of-order entries buffered across all origins (diagnostics).
    pub fn pending(&self) -> usize {
        self.per_origin.values().map(|t| t.pending()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn source_tracker_contiguity() {
        let mut t = SourceTracker::default();
        t.add(1);
        t.add(2);
        assert_eq!(t.highest_contiguous(), 2);
        t.add(5); // gap at 3,4
        assert_eq!(t.highest_contiguous(), 2);
        assert_eq!(t.pending(), 1);
        assert!(t.contains(5) && !t.contains(3));
        t.add_range(3, 4);
        assert_eq!(t.highest_contiguous(), 5);
        assert_eq!(t.pending(), 0);
    }

    #[test]
    fn source_tracker_overlapping_ranges_and_duplicates() {
        let mut t = SourceTracker::default();
        t.add_range(1, 10);
        t.add_range(5, 8); // fully contained
        t.add(3); // duplicate
        assert_eq!(t.highest_contiguous(), 10);
        t.add_range(15, 20);
        t.add_range(8, 14); // bridges the gap, overlapping both sides
        assert_eq!(t.highest_contiguous(), 20);
        t.add_range(7, 3); // inverted range is a no-op
        assert_eq!(t.highest_contiguous(), 20);
    }

    #[test]
    fn source_tracker_random_insertion_order_converges() {
        let mut r = Rng::new(42);
        for _ in 0..50 {
            let mut vals: Vec<u64> = (1..=200).collect();
            r.shuffle(&mut vals);
            let mut t = SourceTracker::default();
            for v in vals {
                t.add(v);
            }
            assert_eq!(t.highest_contiguous(), 200);
            assert_eq!(t.pending(), 0);
        }
    }

    #[test]
    fn majority_watermark_is_order_statistic() {
        // Figure 2: frontiers {2, 3, 2} → stable 2 at majority 2.
        assert_eq!(majority_watermark(&mut [2, 3, 2], 2), 2);
        assert_eq!(majority_watermark(&mut [2, 3, 2], 3), 2);
        assert_eq!(majority_watermark(&mut [2, 3, 2], 1), 3);
        assert_eq!(majority_watermark(&mut [0, 5, 0], 2), 0);
    }

    #[test]
    fn quorum_frontier_tracks_scan() {
        let procs: Vec<ProcessId> = (0..5).map(ProcessId).collect();
        let mut q = QuorumFrontier::new(&procs, 3);
        let mut frontiers = [0u64; 5];
        let mut rng = Rng::new(7);
        let mut last = 0;
        for _ in 0..500 {
            let i = rng.gen_range(5) as usize;
            frontiers[i] += rng.gen_range(4);
            q.update(procs[i], frontiers[i]);
            let mut scan = frontiers;
            let expect = majority_watermark(&mut scan, 3);
            assert_eq!(q.watermark(), expect);
            assert!(q.watermark() >= last, "watermark must be monotone");
            last = q.watermark();
        }
    }

    #[test]
    fn quorum_frontier_ignores_unknown_sources_and_stale_updates() {
        let procs: Vec<ProcessId> = (0..3).map(ProcessId).collect();
        let mut q = QuorumFrontier::new(&procs, 2);
        assert!(!q.update(ProcessId(9), 100));
        assert_eq!(q.watermark(), 0);
        q.update(ProcessId(0), 5);
        q.update(ProcessId(1), 3);
        assert_eq!(q.watermark(), 3);
        assert!(!q.update(ProcessId(1), 2), "stale frontier must be ignored");
        assert_eq!(q.watermark(), 3);
        let unconfigured = QuorumFrontier::default();
        assert!(!unconfigured.is_configured());
        assert_eq!(unconfigured.watermark(), 0);
    }

    #[test]
    fn strided_executed_set_is_dense_within_its_slot() {
        // Worker 2 of 4 owns seqs 3, 7, 11, ...: inserting them in order
        // leaves nothing buffered out of order, and foreign-stride dots
        // read as not-executed.
        let mut s = ExecutedSet::strided(2, 4);
        let origin = ProcessId(3);
        for seq in [3u64, 7, 11, 15] {
            s.insert(Dot::new(origin, seq));
        }
        assert_eq!(s.pending(), 0, "stride must stay contiguous");
        assert!(s.contains(Dot::new(origin, 7)));
        assert!(!s.contains(Dot::new(origin, 4)));
        assert!(!s.contains(Dot::new(origin, 19)));
    }

    #[test]
    fn executed_set_handles_zero_based_sequences() {
        let mut s = ExecutedSet::default();
        let d0 = Dot::new(ProcessId(1), 0);
        let d1 = Dot::new(ProcessId(1), 1);
        assert!(!s.contains(d0));
        s.insert(d0);
        assert!(s.contains(d0) && !s.contains(d1));
        s.insert(d1);
        assert!(s.contains(d1));
        assert_eq!(s.pending(), 0);
    }
}
