//! Shared wire-size accounting for the simulator's CPU/NIC resource model.
//! Every protocol's `Msg::wire_size` previously restated these constants;
//! they live here once so the resource model stays consistent across
//! protocols (and new message kinds — e.g. `MGarbageCollect` — size
//! themselves the same way everywhere).

/// Fixed per-message framing overhead: tag, dot, routing metadata.
pub const HDR: u64 = 24;

/// Wire size of `n` dot references (origin u32 + seq u64).
pub fn dots(n: usize) -> u64 {
    12 * n as u64
}

/// Wire size of `n` (key, u64) pairs (per-key timestamps).
pub fn key_vals(n: usize) -> u64 {
    16 * n as u64
}

/// Wire size of `n` (process, u64) pairs (GC frontiers, ack vectors).
pub fn proc_vals(n: usize) -> u64 {
    12 * n as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_scale_linearly() {
        assert_eq!(dots(0), 0);
        assert_eq!(dots(3), 36);
        assert_eq!(key_vals(2), 32);
        assert_eq!(proc_vals(5), 60);
        assert!(HDR > 0);
    }
}
