//! Outgoing message batching (the fantoch batching layer, see PAPERS
//! "State-Machine Replication for Planet-Scale Systems"): coalesce the
//! protocol messages bound for the same destination into a single `MBatch`
//! wire frame, amortizing per-message framing, syscall and CPU costs.
//!
//! The layer is protocol-agnostic: every protocol `Msg` enum adds one
//! `MBatch` variant and implements [`BatchMsg`]; the per-destination
//! queueing lives here once, inside [`Batcher`], owned by
//! [`super::base::BaseProcess`]. Unbatching happens inside each protocol's
//! `Process::dispatch` (a batch frame simply re-dispatches its members in
//! order), so handlers never see batches. Batching is off by default
//! (`Config::batch_max_msgs == 0`); see `Config::batch_hold` for the two
//! flush policies and `docs/WIRE.md` for the `MBatch` frame layout.

use crate::core::{Config, ProcessId};
use crate::metrics::Counters;
use crate::protocol::Action;
use std::collections::BTreeMap;

/// Implemented by protocol message enums that carry an `MBatch` variant.
///
/// The contract: `batch(msgs)` wraps two or more non-batch messages, and
/// `is_batch` recognizes the wrapper so [`Batcher`] never nests batches
/// (the wire codec rejects nested batches as malformed input).
pub trait BatchMsg: Sized {
    /// Wrap `msgs` into the protocol's batch variant. Callers guarantee
    /// `msgs.len() >= 2` and that no member is itself a batch.
    fn batch(msgs: Vec<Self>) -> Self;

    /// Is this message a batch frame?
    fn is_batch(&self) -> bool;

    /// Approximate encoded size in bytes (protocols delegate to their
    /// `wire_size`). Drives the byte-based flush threshold so a batch
    /// frame can never grow past the transport's frame cap.
    fn approx_wire_bytes(&self) -> u64;
}

/// Byte-based flush threshold per destination queue: a queue whose
/// estimated encoding reaches this flushes immediately, regardless of
/// `Config::batch_max_msgs`. Held at 4 MiB — a quarter of the TCP
/// runtime's `MAX_FRAME_BYTES` (16 MiB) — because `approx_wire_bytes`
/// is an estimate, not the exact encoding; without this cap, a large
/// message-count threshold times promise-heavy messages could build a
/// frame the *receiver* rejects as hostile.
pub const BATCH_SOFT_MAX_BYTES: u64 = 4 << 20;

/// One destination's pending messages: the queue, its summed
/// `approx_wire_bytes`, and when its oldest entry was enqueued (drives
/// the age-based flush, `Config::batch_max_delay_us`).
#[derive(Clone, Debug)]
struct Queue<M> {
    msgs: Vec<M>,
    bytes: u64,
    oldest_at: u64,
}

// Manual impl: a derived Default would demand `M: Default`, which the
// protocol Msg enums do not (and need not) provide.
impl<M> Default for Queue<M> {
    fn default() -> Self {
        Queue { msgs: Vec::new(), bytes: 0, oldest_at: 0 }
    }
}

/// Per-destination coalescing of outgoing [`Action::Send`]s.
///
/// A queue is flushed as one [`BatchMsg::batch`] frame when it reaches
/// `max_msgs` messages or [`BATCH_SOFT_MAX_BYTES`] of estimated encoding
/// (inside [`Batcher::harvest`]). Any remainder is flushed by the policy
/// of `Config::batch_hold`: per protocol step ([`Batcher::flush`], the
/// transparent policy), or held across steps and flushed by the periodic
/// tick once the queue's oldest entry exceeds
/// `Config::batch_max_delay_us` ([`Batcher::flush_due`]; a delay of 0
/// flushes on every tick). Per-destination FIFO order is preserved;
/// self-addressed sends and non-send actions pass through untouched. A
/// queue holding a single message flushes it unwrapped (no one-element
/// batches on the wire).
#[derive(Clone, Debug)]
pub struct Batcher<M> {
    me: ProcessId,
    max_msgs: usize,
    hold: bool,
    max_delay_us: u64,
    queues: BTreeMap<ProcessId, Queue<M>>,
    queued: usize,
    batches_sent: u64,
    batched_msgs: u64,
}

impl<M> Batcher<M> {
    /// Build the batcher for process `me` from the cluster config.
    pub fn from_config(me: ProcessId, config: &Config) -> Self {
        Batcher {
            me,
            // The wire frame's member count is a u16 (docs/WIRE.md).
            max_msgs: config.batch_max_msgs.min(u16::MAX as usize),
            hold: config.batch_hold,
            max_delay_us: config.batch_max_delay_us,
            queues: BTreeMap::new(),
            queued: 0,
            batches_sent: 0,
            batched_msgs: 0,
        }
    }

    /// Is batching on at all? (`Config::batch_max_msgs > 0`.)
    pub fn enabled(&self) -> bool {
        self.max_msgs > 0
    }

    /// Are queues held across protocol steps (flushed on size threshold
    /// or tick) rather than at the end of every step?
    pub fn hold(&self) -> bool {
        self.hold
    }

    /// Messages currently queued across all destinations (diagnostics;
    /// reported through `Footprint::queued`).
    pub fn queued(&self) -> usize {
        self.queued
    }

    /// Fold this batcher's lifetime statistics into `c`.
    pub fn record_stats(&self, c: &mut Counters) {
        c.batches_sent += self.batches_sent;
        c.batched_msgs += self.batched_msgs;
    }
}

// `Clone` alongside `BatchMsg`: a shared fan-out enqueues one copy per
// destination (cheap — protocol messages are `Arc`-backed), and every
// protocol `Msg` is `Clone` already (`Process::Msg: Clone`).
impl<M: BatchMsg + Clone> Batcher<M> {
    /// Route one protocol step's actions through the batcher: remote sends
    /// are queued per destination (emitting a batch whenever a queue
    /// reaches the size threshold); everything else passes through in
    /// order. `now` stamps the age of a queue's oldest entry for
    /// [`Batcher::flush_due`]. With batching disabled this is the
    /// identity.
    pub fn harvest(&mut self, actions: Vec<Action<M>>, now: u64) -> Vec<Action<M>> {
        if !self.enabled() {
            return actions;
        }
        let mut out = Vec::with_capacity(actions.len());
        for action in actions {
            match action {
                Action::Send { to, msg } if to != self.me && !msg.is_batch() => {
                    self.enqueue(to, msg, now, &mut out);
                }
                // A shared fan-out queues per destination like the
                // equivalent sequence of point-to-point sends (clones
                // are cheap: broadcast payloads are `Arc`-backed). The
                // per-peer frame merger downstream restores the
                // single-frame send the batcher splits here. A self
                // destination (broadcast promises `to` never holds one)
                // passes through unbatched, exactly like a self `Send`.
                Action::SendShared { to, msg } if !msg.is_batch() => {
                    for &dest in &to {
                        if dest == self.me {
                            out.push(Action::send(dest, msg.clone()));
                        } else {
                            self.enqueue(dest, msg.clone(), now, &mut out);
                        }
                    }
                }
                other => out.push(other),
            }
        }
        out
    }

    /// Queue one message for `to`, flushing the destination's queue as a
    /// batch if it reached the message-count or byte threshold.
    fn enqueue(&mut self, to: ProcessId, msg: M, now: u64, out: &mut Vec<Action<M>>) {
        let bytes = msg.approx_wire_bytes();
        let q = self.queues.entry(to).or_default();
        if q.msgs.is_empty() {
            q.oldest_at = now;
        }
        q.msgs.push(msg);
        q.bytes += bytes;
        self.queued += 1;
        if q.msgs.len() >= self.max_msgs || q.bytes >= BATCH_SOFT_MAX_BYTES {
            let msgs = std::mem::take(&mut q.msgs);
            q.bytes = 0;
            self.queued -= msgs.len();
            out.push(Action::send(to, self.wrap(msgs)));
        }
    }

    /// Flush every queue: one send per destination holding messages.
    pub fn flush(&mut self) -> Vec<Action<M>> {
        if self.queued == 0 {
            return Vec::new();
        }
        let queues = std::mem::take(&mut self.queues);
        self.queued = 0;
        queues
            .into_iter()
            .filter(|(_, q)| !q.msgs.is_empty())
            .map(|(to, q)| Action::send(to, self.wrap(q.msgs)))
            .collect()
    }

    /// Age-based flush (the periodic tick under `Config::batch_hold`):
    /// flush only the queues whose oldest entry has waited at least
    /// `Config::batch_max_delay_us` — younger queues keep accumulating
    /// for bigger batches. A delay of 0 degenerates to [`Batcher::flush`]
    /// (every held queue drains on every tick), so a lone sub-threshold
    /// message always departs within one delay bound plus one tick.
    pub fn flush_due(&mut self, now: u64) -> Vec<Action<M>> {
        if self.queued == 0 {
            return Vec::new();
        }
        if self.max_delay_us == 0 {
            return self.flush();
        }
        let due: Vec<ProcessId> = self
            .queues
            .iter()
            .filter(|(_, q)| {
                !q.msgs.is_empty() && now.saturating_sub(q.oldest_at) >= self.max_delay_us
            })
            .map(|(&to, _)| to)
            .collect();
        due.into_iter()
            .map(|to| {
                let q = self.queues.get_mut(&to).expect("due queue exists");
                let msgs = std::mem::take(&mut q.msgs);
                q.bytes = 0;
                self.queued -= msgs.len();
                Action::send(to, self.wrap(msgs))
            })
            .collect()
    }

    /// Wrap a drained queue: single messages go out as themselves.
    fn wrap(&mut self, msgs: Vec<M>) -> M {
        debug_assert!(!msgs.is_empty());
        if msgs.len() == 1 {
            return msgs.into_iter().next().expect("non-empty");
        }
        self.batches_sent += 1;
        self.batched_msgs += msgs.len() as u64;
        M::batch(msgs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug, PartialEq)]
    enum TestMsg {
        One(u64),
        /// A message pretending to encode to this many bytes.
        Big(u64),
        Batch(Vec<TestMsg>),
    }

    impl BatchMsg for TestMsg {
        fn batch(msgs: Vec<Self>) -> Self {
            TestMsg::Batch(msgs)
        }

        fn is_batch(&self) -> bool {
            matches!(self, TestMsg::Batch(_))
        }

        fn approx_wire_bytes(&self) -> u64 {
            match self {
                TestMsg::One(_) => 16,
                TestMsg::Big(bytes) => *bytes,
                TestMsg::Batch(msgs) => msgs.iter().map(|m| m.approx_wire_bytes()).sum(),
            }
        }
    }

    fn batcher(max: usize) -> Batcher<TestMsg> {
        let config = Config::new(3, 1).with_batching(max);
        Batcher::from_config(ProcessId(0), &config)
    }

    fn send(to: u32, v: u64) -> Action<TestMsg> {
        Action::send(ProcessId(to), TestMsg::One(v))
    }

    #[test]
    fn disabled_batcher_is_the_identity() {
        let mut b = batcher(0);
        assert!(!b.enabled());
        let out = b.harvest(vec![send(1, 7), send(2, 8)], 0);
        assert_eq!(out.len(), 2);
        assert_eq!(b.queued(), 0);
        assert!(b.flush().is_empty());
    }

    #[test]
    fn size_threshold_flushes_in_fifo_order() {
        let mut b = batcher(2);
        let out = b.harvest(vec![send(1, 1), send(2, 9), send(1, 2), send(1, 3)], 0);
        // P1's queue hit the threshold after (1, 2); (9) and (3) stay queued.
        assert_eq!(out.len(), 1);
        match &out[0] {
            Action::Send { to, msg: TestMsg::Batch(msgs) } => {
                assert_eq!(*to, ProcessId(1));
                assert_eq!(*msgs, vec![TestMsg::One(1), TestMsg::One(2)]);
            }
            other => panic!("expected a batch to P1, got {other:?}"),
        }
        assert_eq!(b.queued(), 2);
        let flushed = b.flush();
        assert_eq!(flushed.len(), 2, "one send per queued destination");
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn single_message_queues_flush_unwrapped() {
        let mut b = batcher(8);
        assert!(b.harvest(vec![send(1, 5)], 0).is_empty());
        let out = b.flush();
        assert_eq!(out.len(), 1);
        assert!(
            matches!(&out[0], Action::Send { msg: TestMsg::One(5), .. }),
            "lone message must not be wrapped: {out:?}"
        );
        let mut c = Counters::default();
        b.record_stats(&mut c);
        assert_eq!(c.batches_sent, 0, "no batch frame for a single message");
    }

    #[test]
    fn self_sends_and_existing_batches_pass_through() {
        let mut b = batcher(4);
        let pre = TestMsg::Batch(vec![TestMsg::One(1), TestMsg::One(2)]);
        let out = b.harvest(vec![send(0, 3), Action::send(ProcessId(2), pre.clone())], 0);
        assert_eq!(out.len(), 2, "self-send and pre-batched frame pass through");
        assert_eq!(b.queued(), 0);
        assert!(matches!(&out[1], Action::Send { msg, .. } if *msg == pre));
    }

    #[test]
    fn shared_fanouts_queue_per_destination() {
        let mut b = batcher(2);
        let fan = Action::SendShared {
            to: vec![ProcessId(1), ProcessId(2)],
            msg: TestMsg::One(7),
        };
        // One shared fan-out counts toward every destination's queue,
        // exactly like the equivalent per-peer sends would.
        let out = b.harvest(vec![fan, send(1, 8)], 0);
        assert_eq!(out.len(), 1, "P1 reached the threshold: {out:?}");
        match &out[0] {
            Action::Send { to, msg: TestMsg::Batch(msgs) } => {
                assert_eq!(*to, ProcessId(1));
                assert_eq!(*msgs, vec![TestMsg::One(7), TestMsg::One(8)]);
            }
            other => panic!("expected a batch to P1, got {other:?}"),
        }
        assert_eq!(b.queued(), 1, "P2 still holds its copy");
        let flushed = b.flush();
        assert!(
            matches!(&flushed[0], Action::Send { to, msg: TestMsg::One(7) } if *to == ProcessId(2))
        );
    }

    #[test]
    fn byte_threshold_flushes_before_the_count_threshold() {
        // Threshold of 1000 messages, but two ~3 MiB messages cross the
        // 4 MiB soft cap and must flush as a frame the transport accepts.
        let mut b = batcher(1000);
        let big = || Action::send(ProcessId(1), TestMsg::Big(3 << 20));
        let out = b.harvest(vec![big(), big()], 0);
        assert_eq!(out.len(), 1, "byte cap must force a flush");
        match &out[0] {
            Action::Send { msg: TestMsg::Batch(msgs), .. } => assert_eq!(msgs.len(), 2),
            other => panic!("expected a batch, got {other:?}"),
        }
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn age_based_flush_holds_until_the_delay_bound() {
        let config =
            Config::new(3, 1).with_batching(100).with_batch_max_delay_us(10_000);
        let mut b: Batcher<TestMsg> = Batcher::from_config(ProcessId(0), &config);
        assert!(b.harvest(vec![send(1, 7)], 1_000).is_empty());
        // Younger than the delay bound: the tick keeps holding it.
        assert!(b.flush_due(6_000).is_empty());
        assert_eq!(b.queued(), 1);
        // A second destination enqueued later gets its own age.
        assert!(b.harvest(vec![send(2, 8)], 7_000).is_empty());
        // At 11 000 µs only P1's queue (age 10 000) is due; P2 (age 4 000)
        // keeps accumulating.
        let out = b.flush_due(11_000);
        assert_eq!(out.len(), 1);
        assert!(matches!(
            &out[0],
            Action::Send { to, msg: TestMsg::One(7) } if *to == ProcessId(1)
        ));
        assert_eq!(b.queued(), 1);
        // ... and departs itself within one delay bound of its enqueue.
        let out = b.flush_due(17_000);
        assert_eq!(out.len(), 1);
        assert!(matches!(
            &out[0],
            Action::Send { to, msg: TestMsg::One(8) } if *to == ProcessId(2)
        ));
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn zero_delay_flushes_every_queue_on_tick() {
        // batch_max_delay_us == 0 (the default) preserves the PR 2
        // behaviour: every held queue drains on every tick.
        let config = Config::new(3, 1).with_batching(100);
        let mut b: Batcher<TestMsg> = Batcher::from_config(ProcessId(0), &config);
        assert!(b.harvest(vec![send(1, 7), send(2, 8)], 5_000).is_empty());
        assert_eq!(b.flush_due(5_000).len(), 2);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn age_resets_once_a_queue_drains() {
        let config = Config::new(3, 1).with_batching(100).with_batch_max_delay_us(1_000);
        let mut b: Batcher<TestMsg> = Batcher::from_config(ProcessId(0), &config);
        assert!(b.harvest(vec![send(1, 1)], 0).is_empty());
        assert_eq!(b.flush_due(1_000).len(), 1);
        // New message after the drain: age is measured from ITS enqueue.
        assert!(b.harvest(vec![send(1, 2)], 1_500).is_empty());
        assert!(b.flush_due(2_000).is_empty(), "age must reset after a drain");
        assert_eq!(b.flush_due(2_500).len(), 1);
    }

    #[test]
    fn stats_count_batches_and_members() {
        let mut b = batcher(3);
        let _ = b.harvest((0..7).map(|v| send(1, v)).collect(), 0);
        let _ = b.flush(); // 3 + 3 batched, then 1 unwrapped
        let mut c = Counters::default();
        b.record_stats(&mut c);
        assert_eq!(c.batches_sent, 2);
        assert_eq!(c.batched_msgs, 6);
        assert_eq!(c.mean_batch_size(), 3.0);
    }
}
