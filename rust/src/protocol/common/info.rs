//! Generic per-command bookkeeping store. Every protocol keeps one `Info`
//! record per [`Dot`]; this wrapper gives them a single creation point and
//! a prune hook for [`super::gc::GCTrack`]-driven garbage collection —
//! the seed kept these maps forever, so memory grew without bound.

use crate::core::Dot;
use std::collections::HashMap;

/// Per-command bookkeeping map: one `I` record per [`Dot`], with a single
/// creation point ([`CommandsInfo::ensure`]) and a GC prune hook.
#[derive(Clone, Debug)]
pub struct CommandsInfo<I> {
    info: HashMap<Dot, I>,
}

impl<I> Default for CommandsInfo<I> {
    fn default() -> Self {
        CommandsInfo { info: HashMap::new() }
    }
}

impl<I> CommandsInfo<I> {
    /// The record for `dot`, if one exists.
    pub fn get(&self, dot: &Dot) -> Option<&I> {
        self.info.get(dot)
    }

    /// Mutable access to the record for `dot`, if one exists.
    pub fn get_mut(&mut self, dot: &Dot) -> Option<&mut I> {
        self.info.get_mut(dot)
    }

    /// Is there a record for `dot`?
    pub fn contains(&self, dot: &Dot) -> bool {
        self.info.contains_key(dot)
    }

    /// The record for `dot`, created with `new` on first touch.
    pub fn ensure(&mut self, dot: Dot, new: impl FnOnce() -> I) -> &mut I {
        self.info.entry(dot).or_insert_with(new)
    }

    /// Insert (or replace) the record for `dot`.
    pub fn insert(&mut self, dot: Dot, info: I) {
        self.info.insert(dot, info);
    }

    /// Drop the record for `dot`; true if one existed.
    pub fn prune(&mut self, dot: &Dot) -> bool {
        self.info.remove(dot).is_some()
    }

    /// Number of retained records (memory diagnostics).
    pub fn len(&self) -> usize {
        self.info.len()
    }

    /// Is the map empty?
    pub fn is_empty(&self) -> bool {
        self.info.is_empty()
    }
}

impl<I> std::ops::Index<&Dot> for CommandsInfo<I> {
    type Output = I;

    fn index(&self, dot: &Dot) -> &I {
        self.info.get(dot).expect("no info for command")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ProcessId;

    #[test]
    fn ensure_creates_once_and_prune_removes() {
        let mut m: CommandsInfo<u32> = CommandsInfo::default();
        let d = Dot::new(ProcessId(0), 1);
        *m.ensure(d, || 7) += 1;
        *m.ensure(d, || 100) += 1; // existing record, ctor not called
        assert_eq!(m[&d], 9);
        assert_eq!(m.len(), 1);
        assert!(m.prune(&d));
        assert!(!m.prune(&d));
        assert!(m.get(&d).is_none() && m.is_empty());
    }
}
