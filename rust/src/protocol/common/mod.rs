//! The shared protocol base layer (the fantoch `BaseProcess`/`GCTrack`
//! factoring, see PAPERS "State-Machine Replication for Planet-Scale
//! Systems"): identity/group/config state with broadcast and stalled-
//! message buffering ([`BaseProcess`]/[`Process`]), outgoing message
//! batching ([`batch`]), generic per-command bookkeeping
//! ([`CommandsInfo`]), group-wide garbage collection of executed commands
//! ([`GCTrack`]), the stability kernel shared with the runtime
//! ([`stability`]), parking for stability-powered local reads
//! ([`read`]), capped-exponential retransmission pacing ([`retry`]),
//! per-key worker sharding of whole replicas
//! ([`shard`]), and wire-size accounting ([`wire`]).
//!
//! Layering: `core` → `protocol/common` → protocol implementations
//! (`tempo`, `depsmr`, `caesar`, `fpaxos`) → `executor`/`runtime` →
//! `sim`/`net`. See ARCHITECTURE.md and docs/WIRE.md.

#![warn(missing_docs)]

pub mod base;
pub mod batch;
pub mod epoch;
pub mod gc;
pub mod info;
pub mod read;
pub mod retry;
pub mod shard;
pub mod stability;
pub mod wire;

pub use base::{BaseProcess, Process};
pub use batch::{BatchMsg, Batcher};
pub use epoch::{EpochManager, EpochProcess};
pub use gc::{GCTrack, GcProcess};
pub use info::CommandsInfo;
pub use read::{ParkedRead, ReadStash};
pub use retry::RetryPacer;
pub use shard::{worker_of_cmd, worker_of_dot, worker_of_key, Routed, Sharded};
pub use stability::{majority_watermark, ExecutedSet, QuorumFrontier, SourceTracker};
