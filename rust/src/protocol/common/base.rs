//! `BaseProcess` + the `Process` trait: the identity/group/config state,
//! broadcast-with-immediate-self-delivery, and stalled-message buffering
//! that every protocol previously hand-rolled (fantoch's `BaseProcess`
//! factoring, adapted to this crate's side-effect-free state machines).

use super::batch::{BatchMsg, Batcher};
use crate::core::{Config, Dot, DotGen, ProcessId, ShardId};
use crate::protocol::Action;
use std::collections::HashMap;

/// State shared by every protocol implementation. Generic over the wire
/// message type `M` so the stalled-message buffer and the outgoing
/// message batcher can live here too.
#[derive(Clone, Debug)]
pub struct BaseProcess<M> {
    /// This process's identifier.
    pub id: ProcessId,
    /// The shard group this process replicates.
    pub group: ShardId,
    /// All machines of our shard group (the paper's `I_p`).
    pub group_procs: Vec<ProcessId>,
    /// The cluster configuration.
    pub config: Config,
    /// Set by `Protocol::crash`; a crashed process ignores all input.
    pub crashed: bool,
    /// Per-destination coalescing of outgoing sends (`Config::batch_max_msgs`).
    pub batcher: Batcher<M>,
    /// Dot allocator for commands submitted at this process (the paper's
    /// `next_id()`): `Protocol::submit` renames each accepted command to
    /// `(id, seq)` here — callers never pre-allocate dots.
    dots: DotGen,
    /// Messages whose precondition is not yet enabled, keyed by the command
    /// (or, for Caesar's wait condition, the blocking command).
    stalled: HashMap<Dot, Vec<(ProcessId, M)>>,
}

impl<M> BaseProcess<M> {
    /// Build the shared state of process `id` under `config`. Under worker
    /// sharding (`config.worker`/`config.workers`, set by
    /// [`super::shard::Sharded`]) the dot generator mints this worker
    /// slot's interleaved sequence stride, so a dot names its owning
    /// worker; the monolithic default is the identity stride.
    pub fn new(id: ProcessId, config: Config) -> Self {
        let group = config.shard_of(id);
        let group_procs = config.shard_processes(group);
        let batcher = Batcher::from_config(id, &config);
        let dots = DotGen::strided(id, config.worker, config.workers);
        BaseProcess {
            id,
            group,
            group_procs,
            config,
            crashed: false,
            batcher,
            dots,
            stalled: HashMap::new(),
        }
    }

    /// Allocate the dot for a freshly submitted command.
    pub fn next_dot(&mut self) -> Dot {
        self.dots.next()
    }

    /// Crash-recovery guard: never mint a dot with sequence `<= floor`
    /// again (see [`crate::protocol::Protocol::note_restart`]).
    pub fn advance_dots_past(&mut self, floor: u64) {
        self.dots.advance_past(floor);
    }

    /// Shard-local process-id base (`group * r`).
    pub fn group_base(&self) -> u32 {
        self.group.0 * self.config.r as u32
    }

    /// Buffer a message from `from` whose precondition (keyed by `dot`)
    /// is not yet enabled.
    pub fn stall(&mut self, dot: Dot, from: ProcessId, msg: M) {
        self.stalled.entry(dot).or_default().push((from, msg));
    }

    /// Remove and return the messages stalled on `dot`.
    pub fn take_stalled(&mut self, dot: Dot) -> Vec<(ProcessId, M)> {
        self.stalled.remove(&dot).unwrap_or_default()
    }

    /// Drop any messages stalled on `dot` without re-handling them (GC).
    pub fn drop_stalled(&mut self, dot: Dot) {
        self.stalled.remove(&dot);
    }

    /// Number of commands with buffered messages (diagnostics).
    pub fn stalled_len(&self) -> usize {
        self.stalled.len()
    }
}

/// Implemented by protocol state machines built on [`BaseProcess`].
/// Provides the shared broadcast (self-addressed messages are delivered
/// immediately, matching the paper) and the stalled-message machinery.
pub trait Process: Sized {
    /// The protocol's wire message type.
    type Msg: Clone;

    /// The shared [`BaseProcess`] state.
    fn base(&self) -> &BaseProcess<Self::Msg>;

    /// Mutable access to the shared [`BaseProcess`] state.
    fn base_mut(&mut self) -> &mut BaseProcess<Self::Msg>;

    /// The single message-dispatch entry point (`Protocol::handle` routes
    /// here; so do self-deliveries and stalled-message replays).
    fn dispatch(
        &mut self,
        from: ProcessId,
        msg: Self::Msg,
        time: u64,
    ) -> Vec<Action<Self::Msg>>;

    /// Send `msg` to every process in `to` except ourselves; handle our own
    /// copy inline. The peer fan-out is emitted as **one**
    /// [`Action::SendShared`] carrying the message a single time — the
    /// runtimes share it across all destinations (the TCP runtime
    /// serializes it once; the simulator expands it into the identical
    /// per-destination deliveries). A single peer degenerates to a plain
    /// point-to-point [`Action::Send`].
    fn broadcast(
        &mut self,
        to: &[ProcessId],
        msg: Self::Msg,
        time: u64,
        out: &mut Vec<Action<Self::Msg>>,
    ) {
        let me = self.base().id;
        let mut to_self = false;
        let mut peers = Vec::with_capacity(to.len());
        for &p in to {
            if p == me {
                to_self = true;
            } else {
                peers.push(p);
            }
        }
        if to_self {
            match peers.len() {
                0 => {}
                1 => out.push(Action::send(peers[0], msg.clone())),
                _ => out.push(Action::SendShared { to: peers, msg: msg.clone() }),
            }
            let actions = self.dispatch(me, msg, time);
            out.extend(actions);
        } else {
            match peers.len() {
                0 => {}
                1 => out.push(Action::send(peers[0], msg)),
                _ => out.push(Action::SendShared { to: peers, msg }),
            }
        }
    }

    /// Buffer a message whose precondition is not yet enabled.
    fn stall(&mut self, dot: Dot, from: ProcessId, msg: Self::Msg) {
        self.base_mut().stall(dot, from, msg);
    }

    /// Re-deliver messages stalled on `dot` after its state advanced.
    fn drain_stalled(&mut self, dot: Dot, time: u64, out: &mut Vec<Action<Self::Msg>>) {
        for (from, msg) in self.base_mut().take_stalled(dot) {
            let actions = self.dispatch(from, msg, time);
            out.extend(actions);
        }
    }

    /// Route one protocol step's actions through the outgoing message
    /// batcher ([`super::batch::Batcher`]). `Protocol::{submit, handle,
    /// tick}` implementations call this exactly once per step, with `tick`
    /// set on the periodic handler so held queues drain at least once per
    /// delay bound (`Config::batch_max_delay_us`; every tick when 0).
    /// With batching disabled this is the identity.
    fn outbound(
        &mut self,
        actions: Vec<Action<Self::Msg>>,
        tick: bool,
        now: u64,
    ) -> Vec<Action<Self::Msg>>
    where
        Self::Msg: BatchMsg,
    {
        let batcher = &mut self.base_mut().batcher;
        if !batcher.enabled() {
            return actions;
        }
        let mut out = batcher.harvest(actions, now);
        if !batcher.hold() {
            out.extend(batcher.flush());
        } else if tick {
            out.extend(batcher.flush_due(now));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Config;

    #[derive(Clone, Debug, PartialEq)]
    enum TestMsg {
        Ping,
        Pong,
    }

    struct Echo {
        bp: BaseProcess<TestMsg>,
        handled: Vec<(ProcessId, TestMsg)>,
    }

    impl Process for Echo {
        type Msg = TestMsg;

        fn base(&self) -> &BaseProcess<TestMsg> {
            &self.bp
        }

        fn base_mut(&mut self) -> &mut BaseProcess<TestMsg> {
            &mut self.bp
        }

        fn dispatch(&mut self, from: ProcessId, msg: TestMsg, _time: u64) -> Vec<Action<TestMsg>> {
            self.handled.push((from, msg));
            Vec::new()
        }
    }

    #[test]
    fn broadcast_delivers_self_copy_inline() {
        let config = Config::new(3, 1);
        let mut p = Echo { bp: BaseProcess::new(ProcessId(1), config), handled: Vec::new() };
        let mut out = Vec::new();
        let to: Vec<ProcessId> = (0..3).map(ProcessId).collect();
        p.broadcast(&to, TestMsg::Ping, 0, &mut out);
        // One shared fan-out to (P0, P2) and one inline self-delivery.
        assert_eq!(out.len(), 1);
        match &out[0] {
            Action::SendShared { to, msg } => {
                assert_eq!(to, &vec![ProcessId(0), ProcessId(2)]);
                assert_eq!(*msg, TestMsg::Ping);
            }
            other => panic!("expected a shared fan-out, got {other:?}"),
        }
        assert_eq!(p.handled, vec![(ProcessId(1), TestMsg::Ping)]);
    }

    #[test]
    fn broadcast_to_one_peer_stays_point_to_point() {
        let config = Config::new(3, 1);
        let mut p = Echo { bp: BaseProcess::new(ProcessId(1), config), handled: Vec::new() };
        let mut out = Vec::new();
        p.broadcast(&[ProcessId(0)], TestMsg::Pong, 0, &mut out);
        assert!(
            matches!(&out[0], Action::Send { to, msg: TestMsg::Pong } if *to == ProcessId(0)),
            "a single-peer fan-out must not be wrapped: {out:?}"
        );
        assert!(p.handled.is_empty());
    }

    #[test]
    fn stalled_messages_replay_once() {
        let config = Config::new(3, 1);
        let mut p = Echo { bp: BaseProcess::new(ProcessId(0), config), handled: Vec::new() };
        let dot = Dot::new(ProcessId(2), 4);
        p.stall(dot, ProcessId(2), TestMsg::Pong);
        assert_eq!(p.base().stalled_len(), 1);
        let mut out = Vec::new();
        p.drain_stalled(dot, 0, &mut out);
        assert_eq!(p.handled, vec![(ProcessId(2), TestMsg::Pong)]);
        p.drain_stalled(dot, 0, &mut out);
        assert_eq!(p.handled.len(), 1, "stalled messages replay exactly once");
    }

    #[test]
    fn base_process_derives_group_from_config() {
        let config = Config::new(3, 1).with_shards(2);
        let bp: BaseProcess<TestMsg> = BaseProcess::new(ProcessId(4), config);
        assert_eq!(bp.group, ShardId(1));
        assert_eq!(bp.group_base(), 3);
        assert_eq!(bp.group_procs, vec![ProcessId(3), ProcessId(4), ProcessId(5)]);
    }
}
