//! The read stash: parking for stability-powered local reads.
//!
//! A read-only command (`Op::Read`) submitted at its coordinator is
//! assigned the replica's *current* timestamp for its keys — no clock
//! bump, no proposal, no quorum. The read can execute the moment the
//! slot's stability frontier covers that timestamp: by timestamp
//! stability (paper §3.2, Theorem 1) no write can ever again acquire a
//! timestamp at or below the frontier, so the read is already ordered
//! against every write that can precede it. Until then the read parks
//! here, keyed by `(release target timestamp, arrival order)` — each
//! protocol worker slot owns one stash, so the `(worker slot, timestamp)`
//! key of the design is the (instance, BTreeMap key) pair.
//!
//! The stash is deliberately protocol-agnostic: it stores commands and
//! release targets and asks the owning protocol — via a predicate over
//! `(command, target)` — which entries its frontier covers. Tempo answers
//! from `PromiseStore`'s cached majority watermark in O(1) per key
//! (`protocol::tempo`); families without a frontier never construct a
//! stash (their `submit_read` degrades to the ordinary ordering path).

use crate::core::{Command, Key};
use std::collections::BTreeMap;

/// One parked (or just-released) read.
#[derive(Clone, Debug)]
pub struct ParkedRead {
    /// The read-only command (op `Op::Read`).
    pub cmd: Command,
    /// Release target: the timestamp the frontier must cover. For strict
    /// reads this is the read's assigned timestamp `ts`; under bounded
    /// staleness (`Config::read_slack = s`) it is `ts - s` — the read
    /// then provably observes every write up to `target` and may miss
    /// writes in `(target, ts]`.
    pub target: u64,
    /// The read's assigned timestamp (max of its keys' clock values at
    /// submission). `target < ts` iff slack was configured.
    pub ts: u64,
}

impl ParkedRead {
    /// Was this read's release target lowered by the staleness slack?
    pub fn slackened(&self) -> bool {
        self.target < self.ts
    }
}

/// Parked reads of one protocol worker slot, ordered by release target so
/// frontier advances release the longest-waiting timestamps first.
#[derive(Debug, Default)]
pub struct ReadStash {
    parked: BTreeMap<(u64, u64), ParkedRead>,
    next_seq: u64,
}

impl ReadStash {
    /// Park a read until the frontier covers `target`.
    pub fn park(&mut self, cmd: Command, target: u64, ts: u64) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.parked.insert((target, seq), ParkedRead { cmd, target, ts });
    }

    /// Release every parked read whose `(command, target)` the owning
    /// protocol's frontier now covers, preserving arrival order within a
    /// release target. Reads on still-uncovered keys stay parked — a
    /// blocked read on a hot key must not hold back a ready read on a
    /// quiet one, so each entry is tested independently.
    pub fn release(&mut self, mut covered: impl FnMut(&Command, u64) -> bool) -> Vec<ParkedRead> {
        if self.parked.is_empty() {
            return Vec::new();
        }
        let ready: Vec<(u64, u64)> = self
            .parked
            .iter()
            .filter(|((target, _), p)| covered(&p.cmd, *target))
            .map(|(&k, _)| k)
            .collect();
        ready.iter().map(|k| self.parked.remove(k).expect("key just listed")).collect()
    }

    /// Number of reads currently parked (footprint diagnostics).
    pub fn len(&self) -> usize {
        self.parked.len()
    }

    /// Is the stash empty? (Cheap fast-path guard for release sweeps.)
    pub fn is_empty(&self) -> bool {
        self.parked.is_empty()
    }

    /// Keys some parked read is waiting on (diagnostics/tests).
    pub fn waiting_keys(&self) -> Vec<Key> {
        let mut keys: Vec<Key> =
            self.parked.values().flat_map(|p| p.cmd.keys.iter().copied()).collect();
        keys.sort_unstable();
        keys.dedup();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Op, Rid};

    fn read(c: u64, keys: Vec<u64>) -> Command {
        Command::new(Rid::new(ClientId(c), 1), keys, Op::Read, 0)
    }

    #[test]
    fn releases_in_target_order_when_frontier_advances() {
        let mut stash = ReadStash::default();
        stash.park(read(1, vec![7]), 5, 5);
        stash.park(read(2, vec![7]), 3, 3);
        stash.park(read(3, vec![7]), 9, 9);
        assert_eq!(stash.len(), 3);
        // Frontier at 5: targets 3 and 5 release (ascending), 9 stays.
        let out = stash.release(|_, target| target <= 5);
        assert_eq!(out.iter().map(|p| p.target).collect::<Vec<_>>(), vec![3, 5]);
        assert_eq!(stash.len(), 1);
        let out = stash.release(|_, target| target <= 10);
        assert_eq!(out.len(), 1);
        assert!(stash.is_empty());
    }

    #[test]
    fn blocked_key_does_not_hold_back_ready_key() {
        let mut stash = ReadStash::default();
        stash.park(read(1, vec![1]), 4, 4); // hot key: frontier lagging
        stash.park(read(2, vec![2]), 8, 8); // quiet key: frontier caught up
        let out = stash.release(|cmd, _| cmd.keys[0] == 2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].cmd.rid.client(), ClientId(2));
        assert_eq!(stash.waiting_keys(), vec![1]);
    }

    #[test]
    fn same_target_preserves_arrival_order() {
        let mut stash = ReadStash::default();
        for c in 0..4 {
            stash.park(read(c, vec![9]), 2, 2);
        }
        let out = stash.release(|_, _| true);
        let clients: Vec<u64> = out.iter().map(|p| p.cmd.rid.client().0).collect();
        assert_eq!(clients, vec![0, 1, 2, 3]);
    }

    #[test]
    fn slackened_reads_know_their_lowered_target() {
        let p = ParkedRead { cmd: read(1, vec![1]), target: 7, ts: 10 };
        assert!(p.slackened());
        let q = ParkedRead { cmd: read(1, vec![1]), target: 10, ts: 10 };
        assert!(!q.slackened());
    }
}
