//! The protocol abstraction shared by Tempo and all baselines.
//!
//! Every protocol is a *deterministic, side-effect-free state machine*:
//! inputs are submitted commands, received messages, and periodic ticks;
//! outputs are [`Action`]s (messages to send, commands executed, protocol
//! events for metrics). The same implementation therefore runs unchanged
//! under the discrete-event simulator, the real TCP runtime, and the tests
//! — and property tests can replay adversarial schedules byte-for-byte.

pub mod atlas;
pub mod common;
pub mod depsmr;
pub mod caesar;
pub mod epaxos;
pub mod fpaxos;
pub mod janus;
pub mod tempo;

use crate::core::{Command, Config, Dot, ProcessId, Response, Rid};

/// Memory-footprint diagnostics: sizes of the per-command/per-key maps a
/// protocol retains. The GC tests assert these stay bounded in long runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct Footprint {
    /// Per-command `Info` records currently held.
    pub infos: usize,
    /// Per-key state entries (key states, conflict tables, log slots).
    pub keys: usize,
    /// Commands with buffered (stalled/blocked) messages.
    pub stalled: usize,
    /// Outgoing messages currently held in the batcher's queues.
    pub queued: usize,
    /// Range fragments held by compacted per-key read sets (the depsmr
    /// `reads_since_write` ranges): the real memory cost of read tracking,
    /// bounded by interleaving rather than read count.
    pub fragments: usize,
}

/// Output of a protocol step.
#[derive(Clone, Debug)]
pub enum Action<M> {
    /// Send `msg` to `to` (point-to-point; self-sends are allowed and are
    /// delivered immediately by the runtimes, matching the paper's
    /// "self-addressed messages are delivered immediately").
    Send { to: ProcessId, msg: M },
    /// The same message fanned out to several destinations
    /// (`protocol::common::Process::broadcast`): one action, one shared
    /// in-memory payload, no per-peer clones. The simulator expands it
    /// into per-destination typed deliveries identical to the equivalent
    /// sequence of `Send`s — determinism proofs are untouched — while
    /// the TCP runtime serializes the message **once** and shares the
    /// encoded body across every destination (lowered to [`Action::SendBytes`]).
    /// `to` never contains the sender (self-copies dispatch inline).
    SendShared { to: Vec<ProcessId>, msg: M },
    /// An already-encoded wire body bound for `to` — the encode-once
    /// byte path: the byte-level lowering of a [`Action::SendShared`]
    /// fan-out (`net::encode_fanout` — the routed frame is serialized a
    /// single time and every destination's `SendBytes` shares the same
    /// `Arc`). The TCP runtime performs this same lowering inline on its
    /// hot send path and writes any `SendBytes` handed to it verbatim as
    /// `[len-prefix, body]` through the per-peer writer. Protocols and
    /// the simulator never produce or consume this variant.
    SendBytes { to: ProcessId, body: std::sync::Arc<[u8]> },
    /// `Protocol::submit` accepted the command and renamed it to `dot`
    /// (oracle/metrics only: the runtimes use it to correlate protocol
    /// identities with client request ids; clients never see it).
    Submitted { dot: Dot },
    /// The command must be applied to the local state machine
    /// (`execute_p`). Consumed in order by the replica's
    /// [`crate::executor::Executor`]. `ts` is the decided ordering
    /// timestamp where the protocol has one (Tempo's final timestamp —
    /// the read-linearizability oracle audits local reads against it);
    /// families without a timestamp order pass 0.
    Execute { dot: Dot, cmd: Command, ts: u64 },
    /// A local read released by the stability frontier: apply `cmd`
    /// (read-only) to the local state machine *now* and reply. Emitted
    /// only at the read's coordinator — the read never acquired a dot,
    /// never traveled, and executes nowhere else. `covered` is the
    /// timestamp the frontier provably covered at release (every write
    /// with decided timestamp <= `covered` on the read's keys has already
    /// executed locally); `slack` records whether the bounded-staleness
    /// level (`Config::read_slack`) allowed an earlier release.
    ExecuteRead { cmd: Command, covered: u64, slack: bool },
    /// The response for request `rid`, emitted by the replica's executor
    /// at the command's coordinator (`dot.origin`) only — the runtimes
    /// route it back to the issuing client session. `ts` is the decided
    /// timestamp the command executed under (a local read reports its
    /// covered target, timestamp-free families report 0): sessions use it
    /// as their read-your-writes floor.
    Reply { rid: Rid, response: Response, ts: u64 },
    /// The command reached the COMMIT phase locally (metrics only).
    Committed { dot: Dot, fast: bool },
    /// A recovery was started for `dot` (metrics only).
    RecoveryStarted { dot: Dot },
}

impl<M> Action<M> {
    pub fn send(to: ProcessId, msg: M) -> Self {
        Action::Send { to, msg }
    }
}

/// Safety margin the runtimes add on top of the recovered dot floor when
/// restarting a replica ([`Protocol::note_restart`]). The WAL and peer
/// manifests only prove floors for dots that *executed*; a dot minted and
/// broadcast just before the crash may live on in peers' consensus state
/// without appearing in any floor. Skipping this many extra sequence
/// numbers makes re-minting such a dot (and binding it to a different
/// command) impossible in practice — sequences are u64, so the skip costs
/// nothing.
pub const RESTART_DOT_SLACK: u64 = 1 << 20;

/// A deterministic message-driven replication protocol.
pub trait Protocol: Sized {
    /// Wire message type.
    type Message: Clone + std::fmt::Debug;

    /// Construct the state of process `id` under `config`.
    fn new(id: ProcessId, config: Config) -> Self;

    /// Protocol name for reporting.
    fn name() -> &'static str;

    /// A client session submits `cmd` at this process (which must
    /// replicate one of the partitions the command accesses). The
    /// protocol allocates the command's `Dot` internally (from the
    /// `BaseProcess` dot generator) and reports it via
    /// [`Action::Submitted`]; callers identify the request by `cmd.rid`.
    fn submit(&mut self, cmd: Command, time_us: u64) -> Vec<Action<Self::Message>>;

    /// A client session submits a *read-only* command (`Op::Read`).
    /// Protocols with a stability frontier (Tempo) override this to serve
    /// the read locally — no broadcast, no quorum, no dot — releasing it
    /// via [`Action::ExecuteRead`] once the frontier covers its
    /// timestamp. `floor` is the session's read-your-writes watermark
    /// (the decided timestamp of its last acknowledged write, 0 for
    /// none): the read must observe state at least that fresh, so a
    /// frontier-serving protocol clamps the read's target timestamp up to
    /// it. The default degrades to [`Protocol::submit`]: the read runs as
    /// an ordinary command through the full ordering path (a "slow
    /// read"), which serializes after the session's own writes and so
    /// satisfies any floor for free.
    fn submit_read(
        &mut self,
        cmd: Command,
        floor: u64,
        time_us: u64,
    ) -> Vec<Action<Self::Message>> {
        let _ = floor;
        self.submit(cmd, time_us)
    }

    /// Handle a message from `from`.
    fn handle(
        &mut self,
        from: ProcessId,
        msg: Self::Message,
        time_us: u64,
    ) -> Vec<Action<Self::Message>>;

    /// Periodic handler (promise broadcast, executor run, recovery timers).
    /// Runtimes call this every `config.tick_interval_us`.
    fn tick(&mut self, time_us: u64) -> Vec<Action<Self::Message>>;

    /// Marks a process as crashed for the rest of the run. Runtimes stop
    /// delivering to it; the default needs no protocol action.
    fn crash(&mut self) {}

    /// Crash-*recovery* hook: a freshly constructed instance is told the
    /// highest own-origin dot sequence its pre-crash incarnation is known
    /// to have minted (from the recovered WAL/snapshot floors plus peer
    /// manifests). The instance must never re-mint a dot `<= floor` —
    /// peers may hold state for those. The default is a no-op for
    /// protocols whose runtimes never restart them.
    fn note_restart(&mut self, _dot_floor: u64) {}

    /// Failure-detector input: `p` is suspected to have crashed
    /// (drives Ω leader election where the protocol needs it).
    fn suspect(&mut self, _p: ProcessId) {}

    /// Protocol event counters for reporting (fast/slow path, recoveries).
    fn counters(&self) -> crate::metrics::Counters {
        crate::metrics::Counters::default()
    }

    /// Approximate wire size of a message in bytes (drives the simulator's
    /// CPU/NIC resource model).
    fn msg_size(_msg: &Self::Message) -> u64 {
        64
    }

    /// Sizes of the retained per-command/per-key maps (GC diagnostics).
    fn footprint(&self) -> Footprint {
        Footprint::default()
    }

    /// The process's installed epoch history — `(epoch, evicted members)`
    /// pairs, oldest first, starting at `(0, [])`. The checker's
    /// `EpochRegression`/`EpochDivergence` oracles audit these; protocols
    /// without reconfiguration report the static epoch-0 view.
    fn epoch_view(&self) -> Vec<(u64, Vec<ProcessId>)> {
        vec![(0, Vec::new())]
    }
}

/// Paxos-style ballot numbering shared by Tempo, FPaxos and the
/// dependency-based baselines.
///
/// Ballots for a command are allocated round-robin: ballot `i` (1..=r) is
/// reserved for the initial coordinator `i`, and ballots `> r` belong to
/// processes performing recovery, with owner `bal_leader(b)`.
pub mod ballot {
    use crate::core::ProcessId;

    /// Owner of ballot `b` among `r` processes whose ids occupy
    /// `base..base+r` (shard-local numbering).
    pub fn leader(b: u64, r: u64, base: u32) -> ProcessId {
        debug_assert!(b >= 1);
        ProcessId(base + ((b - 1) % r) as u32)
    }

    /// The next ballot owned by `p` strictly greater than `cur`
    /// (paper line 74: `b = i + r(⌊(bal-1)/r⌋ + 1)` in shard-local ids).
    pub fn next_owned(cur: u64, p: ProcessId, r: u64, base: u32) -> u64 {
        let i = (p.0 - base) as u64 + 1; // 1-based rank within the shard
        let round = if cur == 0 { 0 } else { (cur - 1) / r + 1 };
        let mut b = i + r * round;
        // next_owned must be > cur even when cur is owned by p itself.
        while b <= cur {
            b += r;
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::ballot;
    use crate::core::ProcessId;

    #[test]
    fn initial_ballots_belong_to_their_coordinator() {
        let r = 5;
        for i in 0..5u32 {
            assert_eq!(ballot::leader(i as u64 + 1, r, 0), ProcessId(i));
        }
    }

    #[test]
    fn next_owned_is_owned_and_increasing() {
        let r = 5;
        for p in 0..5u32 {
            let p = ProcessId(p);
            let mut cur = 0;
            for _ in 0..10 {
                let b = ballot::next_owned(cur, p, r, 0);
                assert!(b > cur);
                assert_eq!(ballot::leader(b, r, 0), p);
                cur = b;
            }
        }
    }

    #[test]
    fn next_owned_with_shard_base() {
        let r = 3;
        let base = 6; // shard 2 of r=3
        let p = ProcessId(7);
        let b = ballot::next_owned(0, p, r, base);
        assert_eq!(ballot::leader(b, r, base), p);
    }
}
