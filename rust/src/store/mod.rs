//! Replicated state machines: the pluggable [`StateMachine`] trait the
//! executor applies commands to, and the in-memory key-value store the
//! paper's framework ships (§6.1) as its first implementation. Executed
//! commands reach a state machine through the `execute_p` upcall
//! (`executor::Executor`); determinism is what PSMR replicates.

use crate::core::{Command, Dot, Key, Op};
use std::collections::HashMap;

pub mod storage;

pub use crate::core::Response;

/// A deterministic state machine replicated by the protocols. The
/// executor applies committed commands in the agreed order; `apply` must
/// be a pure function of the command sequence so every replica converges
/// (and the PSMR response-validity check can replay it as an oracle).
pub trait StateMachine {
    /// Apply `cmd`, mutating local state, and produce the client response.
    fn apply(&mut self, cmd: &Command) -> Response;

    /// Order-sensitive digest of the current state: replicas that applied
    /// the same command sequence must agree (tests and the e2e driver).
    fn digest(&self) -> u64;

    /// Durability hook: called by the executor after a *fresh* ordered
    /// execution (never for dedup replays or local reads) with the dot and
    /// decided timestamp under which `cmd` executed. The in-memory store
    /// ignores it; [`storage::Durable`] appends a WAL record.
    fn log_execution(&mut self, _dot: Dot, _ts: u64, _cmd: &Command) {}

    /// Durability hook: does the machine want a checkpoint now? The
    /// executor polls this after each batch of executions and passes its
    /// serialized dedup windows to [`StateMachine::checkpoint`].
    fn wants_checkpoint(&self) -> bool {
        false
    }

    /// Durability hook: take a snapshot capturing current state plus the
    /// executor's dedup-window blob (so exactly-once survives restart).
    fn checkpoint(&mut self, _dedup: &[u8]) {}
}

/// Maximum entries per content-addressed snapshot page. Small enough that
/// a localized write invalidates one page, large enough that manifests
/// stay compact (a 64k-key store is ~1k chunk hashes).
pub const CHUNK_KEYS: usize = 64;

/// A state machine that can be serialized as sorted, content-addressable
/// pages and rebuilt from any replica's pages — the snapshot / state
/// transfer seam. Page boundaries depend only on the sorted key set, so
/// two replicas with mostly-equal state produce mostly-equal pages and a
/// manifest diff transfers only what differs.
pub trait Snapshottable: StateMachine + Sized {
    /// Total commands applied (replay bookkeeping for recovery).
    fn applied(&self) -> u64;

    /// Serialize as pages of at most [`CHUNK_KEYS`] entries, in sorted key
    /// order. Must be a pure function of state: equal stores chunk equally.
    fn to_chunks(&self) -> Vec<Vec<u8>>;

    /// Rebuild from pages produced by `to_chunks` (this machine's or a
    /// remote's), adopting `applied` as the replay position.
    fn from_chunks(chunks: &[Vec<u8>], applied: u64) -> Self;
}

/// Value stored per key: a version counter plus the payload length that
/// last wrote it (payload bytes themselves are irrelevant to ordering, so
/// we store a digest-sized summary — keeps memory bounded in long runs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Value {
    pub version: u64,
    pub last_payload: u32,
}

/// Deterministic in-memory KV store.
#[derive(Clone, Debug, Default)]
pub struct KvStore {
    data: HashMap<Key, Value>,
    applied: u64,
}

impl KvStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply `cmd` to the local state; returns the client response.
    pub fn execute(&mut self, cmd: &Command) -> Response {
        self.applied += 1;
        let mut versions = Vec::with_capacity(cmd.keys.len());
        for &k in cmd.keys.iter() {
            let v = self.data.entry(k).or_default();
            match cmd.op {
                // The local-read class observes exactly what a Get
                // observes; neither mutates.
                Op::Get | Op::Read => versions.push((k, v.version)),
                Op::Put => {
                    v.version += 1;
                    v.last_payload = cmd.payload_len;
                    versions.push((k, v.version));
                }
                Op::Rmw => {
                    // read-modify-write: bump version deterministically
                    // from the observed value.
                    v.version = v.version + 1 + (v.last_payload as u64 % 2);
                    v.last_payload = cmd.payload_len;
                    versions.push((k, v.version));
                }
            }
        }
        Response { versions }
    }

    pub fn get(&self, k: Key) -> Option<Value> {
        self.data.get(&k).copied()
    }

    pub fn applied(&self) -> u64 {
        self.applied
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Digest of the whole store — replicas that executed the same command
    /// sequence must agree (used by tests and the e2e driver).
    pub fn digest(&self) -> u64 {
        let mut keys: Vec<_> = self.data.iter().collect();
        keys.sort_by_key(|(k, _)| **k);
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for (k, v) in keys {
            mix(*k);
            mix(v.version);
            mix(v.last_payload as u64);
        }
        h
    }
}

impl StateMachine for KvStore {
    fn apply(&mut self, cmd: &Command) -> Response {
        self.execute(cmd)
    }

    fn digest(&self) -> u64 {
        KvStore::digest(self)
    }
}

impl Snapshottable for KvStore {
    fn applied(&self) -> u64 {
        self.applied
    }

    /// Page format (LE): `count u16`, then per entry `key u64`,
    /// `version u64`, `last_payload u32`. Entries are globally sorted by
    /// key and paged [`CHUNK_KEYS`] at a time, so page contents (and thus
    /// their content hashes) are a pure function of store state.
    fn to_chunks(&self) -> Vec<Vec<u8>> {
        let mut entries: Vec<(Key, Value)> =
            self.data.iter().map(|(k, v)| (*k, *v)).collect();
        entries.sort_by_key(|(k, _)| *k);
        entries
            .chunks(CHUNK_KEYS)
            .map(|page| {
                let mut buf = Vec::with_capacity(2 + page.len() * 20);
                buf.extend_from_slice(&(page.len() as u16).to_le_bytes());
                for (k, v) in page {
                    buf.extend_from_slice(&k.to_le_bytes());
                    buf.extend_from_slice(&v.version.to_le_bytes());
                    buf.extend_from_slice(&v.last_payload.to_le_bytes());
                }
                buf
            })
            .collect()
    }

    fn from_chunks(chunks: &[Vec<u8>], applied: u64) -> Self {
        let mut data = HashMap::new();
        for chunk in chunks {
            if chunk.len() < 2 {
                continue;
            }
            let count = u16::from_le_bytes([chunk[0], chunk[1]]) as usize;
            let mut at = 2;
            for _ in 0..count {
                if at + 20 > chunk.len() {
                    break;
                }
                let k = u64::from_le_bytes(chunk[at..at + 8].try_into().unwrap());
                let version =
                    u64::from_le_bytes(chunk[at + 8..at + 16].try_into().unwrap());
                let last_payload =
                    u32::from_le_bytes(chunk[at + 16..at + 20].try_into().unwrap());
                data.insert(k, Value { version, last_payload });
                at += 20;
            }
        }
        KvStore { data, applied }
    }
}

/// FNV-1a over the two children, with distinct seeds for leaves and odd
/// promotions so the tree shape is part of the hash.
fn merkle_mix(a: u64, b: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in [a, b] {
        for byte in x.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

const MERKLE_LEAF_SEED: u64 = 0x6c6561_66; // "leaf"
const MERKLE_ODD_SEED: u64 = 0x6f6464; // "odd"

/// Merkle-style root over per-worker-slot digests: hash each leaf with
/// its position implied by tree shape, then combine pairwise up the
/// tree. Unlike the XOR the TCP runtime used before, equal roots mean
/// equal **leaf vectors** — two compensating slot differences cannot
/// cancel — and an unequal root is localized to the diverging worker
/// slot(s) by comparing the leaves directly ([`diverging_slots`]).
pub fn merkle_root(leaves: &[u64]) -> u64 {
    if leaves.is_empty() {
        return 0;
    }
    let mut level: Vec<u64> =
        leaves.iter().map(|&l| merkle_mix(MERKLE_LEAF_SEED, l)).collect();
    while level.len() > 1 {
        level = level
            .chunks(2)
            .map(|c| {
                if c.len() == 2 {
                    merkle_mix(c[0], c[1])
                } else {
                    merkle_mix(c[0], MERKLE_ODD_SEED)
                }
            })
            .collect();
    }
    level[0]
}

/// Which worker slots two replicas disagree on, given their per-slot
/// digest vectors (a length mismatch reports the tail slots of the
/// longer vector). Empty ⇔ the vectors (and so the Merkle roots) agree.
pub fn diverging_slots(a: &[u64], b: &[u64]) -> Vec<usize> {
    let n = a.len().max(b.len());
    (0..n).filter(|&i| a.get(i) != b.get(i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Rid};

    fn rid(c: u64) -> Rid {
        Rid::new(ClientId(c), 1)
    }

    #[test]
    fn deterministic_across_replicas() {
        let cmds: Vec<Command> = (0..100)
            .map(|i| {
                Command::new(
                    rid(i),
                    vec![i % 7, (i * 3) % 7],
                    if i % 3 == 0 { Op::Get } else { Op::Put },
                    (i % 50) as u32,
                )
            })
            .collect();
        let mut a = KvStore::new();
        let mut b = KvStore::new();
        for c in &cmds {
            let ra = a.execute(c);
            let rb = b.execute(c);
            assert_eq!(ra, rb);
        }
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn order_changes_digest() {
        let w1 = Command::single(rid(1), 5, Op::Put, 10);
        let w2 = Command::single(rid(2), 5, Op::Rmw, 20);
        let mut a = KvStore::new();
        a.execute(&w1);
        a.execute(&w2);
        let mut b = KvStore::new();
        b.execute(&w2);
        b.execute(&w1);
        assert_ne!(a.digest(), b.digest(), "RMW vs PUT order must be observable");
    }

    #[test]
    fn reads_do_not_mutate() {
        let mut s = KvStore::new();
        s.execute(&Command::single(rid(1), 9, Op::Put, 1));
        let d = s.digest();
        s.execute(&Command::single(rid(2), 9, Op::Get, 0));
        assert_eq!(s.digest(), d);
        assert_eq!(s.get(9).unwrap().version, 1);
    }

    #[test]
    fn local_read_class_observes_what_get_observes() {
        let mut s = KvStore::new();
        s.execute(&Command::single(rid(1), 9, Op::Put, 1));
        let d = s.digest();
        let get = s.execute(&Command::single(rid(2), 9, Op::Get, 0));
        let read = s.execute(&Command::read(rid(3), vec![9]));
        assert_eq!(get.versions, read.versions);
        assert_eq!(s.digest(), d, "Op::Read must not mutate");
    }

    #[test]
    fn merkle_root_localizes_and_never_cancels() {
        let slots = vec![11u64, 22, 33, 44];
        assert_eq!(merkle_root(&slots), merkle_root(&slots.clone()), "deterministic");
        // Single-slot divergence flips the root and is localized.
        let mut bad = slots.clone();
        bad[2] ^= 1;
        assert_ne!(merkle_root(&slots), merkle_root(&bad));
        assert_eq!(diverging_slots(&slots, &bad), vec![2]);
        // The XOR pitfall: two compensating slot differences XOR to the
        // same combined value but must NOT produce the same root.
        let mut swapped = slots.clone();
        swapped[0] ^= 0xFF;
        swapped[1] ^= 0xFF;
        assert_eq!(
            slots.iter().fold(0u64, |acc, d| acc ^ d),
            swapped.iter().fold(0u64, |acc, d| acc ^ d),
            "XOR cannot tell these apart...",
        );
        assert_ne!(merkle_root(&slots), merkle_root(&swapped), "...the Merkle root can");
        assert_eq!(diverging_slots(&slots, &swapped), vec![0, 1]);
        // Tree-shape sensitivity: odd leaf counts, prefixes, empty.
        assert_ne!(merkle_root(&slots), merkle_root(&slots[..3]));
        assert_ne!(merkle_root(&[0]), merkle_root(&[0, 0]));
        assert_eq!(merkle_root(&[]), 0);
        assert!(diverging_slots(&slots, &slots[..3]).contains(&3));
    }

    #[test]
    fn chunk_roundtrip_preserves_digest_and_localizes_change() {
        let mut s = KvStore::new();
        for i in 0..(3 * CHUNK_KEYS as u64 + 17) {
            s.execute(&Command::single(rid(i), i, Op::Put, (i % 9) as u32));
        }
        let chunks = s.to_chunks();
        assert_eq!(chunks.len(), 4, "ceil(209 keys / 64 per page)");
        let back = KvStore::from_chunks(&chunks, s.applied());
        assert_eq!(back.digest(), s.digest());
        assert_eq!(back.applied(), s.applied());
        // Updating one existing key changes only the page holding it:
        // content addressing makes incremental snapshots/transfer cheap.
        s.execute(&Command::single(rid(999), 5, Op::Put, 3));
        let after = s.to_chunks();
        let differing = chunks
            .iter()
            .zip(after.iter())
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(differing, 1, "stable key set => one dirty page");
    }

    #[test]
    fn state_machine_trait_matches_execute() {
        // The trait path and the inherent path are the same computation.
        let cmd = Command::single(rid(1), 5, Op::Rmw, 10);
        let mut a = KvStore::new();
        let mut b = KvStore::new();
        let ra = a.execute(&cmd);
        let rb = StateMachine::apply(&mut b, &cmd);
        assert_eq!(ra, rb);
        assert_eq!(StateMachine::digest(&a), StateMachine::digest(&b));
    }
}
