//! In-memory key-value store: the replicated state machine the paper's
//! framework ships (§6.1). Executed commands are applied here through the
//! `execute_p` upcall; determinism is what PSMR replicates.

use crate::core::{Command, Key, Op};
use std::collections::HashMap;

/// Value stored per key: a version counter plus the payload length that
/// last wrote it (payload bytes themselves are irrelevant to ordering, so
/// we store a digest-sized summary — keeps memory bounded in long runs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Value {
    pub version: u64,
    pub last_payload: u32,
}

/// Response returned to the client for one command.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Response {
    /// Per accessed key: version observed (reads) or produced (writes).
    pub versions: Vec<(Key, u64)>,
}

/// Deterministic in-memory KV store.
#[derive(Clone, Debug, Default)]
pub struct KvStore {
    data: HashMap<Key, Value>,
    applied: u64,
}

impl KvStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply `cmd` to the local state; returns the client response.
    pub fn execute(&mut self, cmd: &Command) -> Response {
        self.applied += 1;
        let mut versions = Vec::with_capacity(cmd.keys.len());
        for &k in &cmd.keys {
            let v = self.data.entry(k).or_default();
            match cmd.op {
                Op::Get => versions.push((k, v.version)),
                Op::Put => {
                    v.version += 1;
                    v.last_payload = cmd.payload_len;
                    versions.push((k, v.version));
                }
                Op::Rmw => {
                    // read-modify-write: bump version deterministically
                    // from the observed value.
                    v.version = v.version + 1 + (v.last_payload as u64 % 2);
                    v.last_payload = cmd.payload_len;
                    versions.push((k, v.version));
                }
            }
        }
        Response { versions }
    }

    pub fn get(&self, k: Key) -> Option<Value> {
        self.data.get(&k).copied()
    }

    pub fn applied(&self) -> u64 {
        self.applied
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Digest of the whole store — replicas that executed the same command
    /// sequence must agree (used by tests and the e2e driver).
    pub fn digest(&self) -> u64 {
        let mut keys: Vec<_> = self.data.iter().collect();
        keys.sort_by_key(|(k, _)| **k);
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for (k, v) in keys {
            mix(*k);
            mix(v.version);
            mix(v.last_payload as u64);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ClientId;

    #[test]
    fn deterministic_across_replicas() {
        let cmds: Vec<Command> = (0..100)
            .map(|i| {
                Command::new(
                    ClientId(i),
                    vec![i % 7, (i * 3) % 7],
                    if i % 3 == 0 { Op::Get } else { Op::Put },
                    (i % 50) as u32,
                )
            })
            .collect();
        let mut a = KvStore::new();
        let mut b = KvStore::new();
        for c in &cmds {
            let ra = a.execute(c);
            let rb = b.execute(c);
            assert_eq!(ra, rb);
        }
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn order_changes_digest() {
        let w1 = Command::single(ClientId(1), 5, Op::Put, 10);
        let w2 = Command::single(ClientId(2), 5, Op::Rmw, 20);
        let mut a = KvStore::new();
        a.execute(&w1);
        a.execute(&w2);
        let mut b = KvStore::new();
        b.execute(&w2);
        b.execute(&w1);
        assert_ne!(a.digest(), b.digest(), "RMW vs PUT order must be observable");
    }

    #[test]
    fn reads_do_not_mutate() {
        let mut s = KvStore::new();
        s.execute(&Command::single(ClientId(1), 9, Op::Put, 1));
        let d = s.digest();
        s.execute(&Command::single(ClientId(2), 9, Op::Get, 0));
        assert_eq!(s.digest(), d);
        assert_eq!(s.get(9).unwrap().version, 1);
    }
}
