//! Replicated state machines: the pluggable [`StateMachine`] trait the
//! executor applies commands to, and the in-memory key-value store the
//! paper's framework ships (§6.1) as its first implementation. Executed
//! commands reach a state machine through the `execute_p` upcall
//! (`executor::Executor`); determinism is what PSMR replicates.

use crate::core::{Command, Key, Op};
use std::collections::HashMap;

pub use crate::core::Response;

/// A deterministic state machine replicated by the protocols. The
/// executor applies committed commands in the agreed order; `apply` must
/// be a pure function of the command sequence so every replica converges
/// (and the PSMR response-validity check can replay it as an oracle).
pub trait StateMachine {
    /// Apply `cmd`, mutating local state, and produce the client response.
    fn apply(&mut self, cmd: &Command) -> Response;

    /// Order-sensitive digest of the current state: replicas that applied
    /// the same command sequence must agree (tests and the e2e driver).
    fn digest(&self) -> u64;
}

/// Value stored per key: a version counter plus the payload length that
/// last wrote it (payload bytes themselves are irrelevant to ordering, so
/// we store a digest-sized summary — keeps memory bounded in long runs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Value {
    pub version: u64,
    pub last_payload: u32,
}

/// Deterministic in-memory KV store.
#[derive(Clone, Debug, Default)]
pub struct KvStore {
    data: HashMap<Key, Value>,
    applied: u64,
}

impl KvStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply `cmd` to the local state; returns the client response.
    pub fn execute(&mut self, cmd: &Command) -> Response {
        self.applied += 1;
        let mut versions = Vec::with_capacity(cmd.keys.len());
        for &k in cmd.keys.iter() {
            let v = self.data.entry(k).or_default();
            match cmd.op {
                Op::Get => versions.push((k, v.version)),
                Op::Put => {
                    v.version += 1;
                    v.last_payload = cmd.payload_len;
                    versions.push((k, v.version));
                }
                Op::Rmw => {
                    // read-modify-write: bump version deterministically
                    // from the observed value.
                    v.version = v.version + 1 + (v.last_payload as u64 % 2);
                    v.last_payload = cmd.payload_len;
                    versions.push((k, v.version));
                }
            }
        }
        Response { versions }
    }

    pub fn get(&self, k: Key) -> Option<Value> {
        self.data.get(&k).copied()
    }

    pub fn applied(&self) -> u64 {
        self.applied
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Digest of the whole store — replicas that executed the same command
    /// sequence must agree (used by tests and the e2e driver).
    pub fn digest(&self) -> u64 {
        let mut keys: Vec<_> = self.data.iter().collect();
        keys.sort_by_key(|(k, _)| **k);
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for (k, v) in keys {
            mix(*k);
            mix(v.version);
            mix(v.last_payload as u64);
        }
        h
    }
}

impl StateMachine for KvStore {
    fn apply(&mut self, cmd: &Command) -> Response {
        self.execute(cmd)
    }

    fn digest(&self) -> u64 {
        KvStore::digest(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Rid};

    fn rid(c: u64) -> Rid {
        Rid::new(ClientId(c), 1)
    }

    #[test]
    fn deterministic_across_replicas() {
        let cmds: Vec<Command> = (0..100)
            .map(|i| {
                Command::new(
                    rid(i),
                    vec![i % 7, (i * 3) % 7],
                    if i % 3 == 0 { Op::Get } else { Op::Put },
                    (i % 50) as u32,
                )
            })
            .collect();
        let mut a = KvStore::new();
        let mut b = KvStore::new();
        for c in &cmds {
            let ra = a.execute(c);
            let rb = b.execute(c);
            assert_eq!(ra, rb);
        }
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn order_changes_digest() {
        let w1 = Command::single(rid(1), 5, Op::Put, 10);
        let w2 = Command::single(rid(2), 5, Op::Rmw, 20);
        let mut a = KvStore::new();
        a.execute(&w1);
        a.execute(&w2);
        let mut b = KvStore::new();
        b.execute(&w2);
        b.execute(&w1);
        assert_ne!(a.digest(), b.digest(), "RMW vs PUT order must be observable");
    }

    #[test]
    fn reads_do_not_mutate() {
        let mut s = KvStore::new();
        s.execute(&Command::single(rid(1), 9, Op::Put, 1));
        let d = s.digest();
        s.execute(&Command::single(rid(2), 9, Op::Get, 0));
        assert_eq!(s.digest(), d);
        assert_eq!(s.get(9).unwrap().version, 1);
    }

    #[test]
    fn state_machine_trait_matches_execute() {
        // The trait path and the inherent path are the same computation.
        let cmd = Command::single(rid(1), 5, Op::Rmw, 10);
        let mut a = KvStore::new();
        let mut b = KvStore::new();
        let ra = a.execute(&cmd);
        let rb = StateMachine::apply(&mut b, &cmd);
        assert_eq!(ra, rb);
        assert_eq!(StateMachine::digest(&a), StateMachine::digest(&b));
    }
}
