//! Write-ahead log record format: CRC-framed records of executed
//! `(rid, dot, cmd)` triples (the rid travels inside the command), one
//! log per worker slot.
//!
//! Frame layout (all LE): `[body_len u32][crc32 u32][body]` where the
//! CRC-32 (IEEE) covers the body only. Body layout:
//!
//! ```text
//! index u64      applied count after this record (snapshot cut point)
//! dot            origin u32, seq u64
//! ts u64         decided timestamp the command executed under
//! rid            client u64, seq u64
//! op u8          0 Get, 1 Put, 2 Rmw, 3 Read (same mapping as the wire)
//! payload_len u32
//! batched u32
//! nkeys u16, then key u64 each
//! ```
//!
//! Payload *bytes* are never materialized — their contents are irrelevant
//! to ordering (the store keeps only `payload_len`), and omitting them is
//! what keeps WAL write amplification below the 3x-of-in-memory budget.
//!
//! Replay ([`decode_records`]) accepts the longest valid prefix: a torn
//! final frame (truncated length, short body) or a CRC mismatch ends the
//! log, which is exactly the crash-consistency contract group-commit
//! fsync gives us — a record is either fully durable or not replayed.

use crate::core::{ClientId, Command, Dot, Op, ProcessId, Rid};

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) lookup table,
/// built at compile time — the repo has zero external dependencies.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// One executed command, as logged: the dot and decided timestamp it
/// executed under, plus the command itself (which carries the rid).
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    /// Applied count *after* this record — lets recovery skip records
    /// already captured by a snapshot with `manifest.applied >= index`.
    pub index: u64,
    pub dot: Dot,
    pub ts: u64,
    pub cmd: Command,
}

impl WalRecord {
    /// Encode as a framed record (`[len][crc][body]`), appending to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.extend_from_slice(&[0u8; 8]); // len + crc placeholder
        let body = out.len();
        out.extend_from_slice(&self.index.to_le_bytes());
        out.extend_from_slice(&self.dot.origin.0.to_le_bytes());
        out.extend_from_slice(&self.dot.seq.to_le_bytes());
        out.extend_from_slice(&self.ts.to_le_bytes());
        out.extend_from_slice(&self.cmd.rid.client().0.to_le_bytes());
        out.extend_from_slice(&self.cmd.rid.seq().to_le_bytes());
        out.push(match self.cmd.op {
            Op::Get => 0,
            Op::Put => 1,
            Op::Rmw => 2,
            Op::Read => 3,
        });
        out.extend_from_slice(&self.cmd.payload_len.to_le_bytes());
        out.extend_from_slice(&self.cmd.batched.to_le_bytes());
        out.extend_from_slice(&(self.cmd.keys.len() as u16).to_le_bytes());
        for &k in self.cmd.keys.iter() {
            out.extend_from_slice(&k.to_le_bytes());
        }
        let len = (out.len() - body) as u32;
        let crc = crc32(&out[body..]);
        out[start..start + 4].copy_from_slice(&len.to_le_bytes());
        out[start + 4..start + 8].copy_from_slice(&crc.to_le_bytes());
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + 8 * self.cmd.keys.len());
        self.encode_into(&mut out);
        out
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.at + n > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Some(s)
    }
    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }
    fn u16(&mut self) -> Option<u16> {
        Some(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn decode_body(body: &[u8]) -> Option<WalRecord> {
    let mut c = Cursor { buf: body, at: 0 };
    let index = c.u64()?;
    let dot = Dot::new(ProcessId(c.u32()?), c.u64()?);
    let ts = c.u64()?;
    let rid = Rid::new(ClientId(c.u64()?), c.u64()?);
    let op = match c.u8()? {
        0 => Op::Get,
        1 => Op::Put,
        2 => Op::Rmw,
        3 => Op::Read,
        _ => return None,
    };
    let payload_len = c.u32()?;
    let batched = c.u32()?;
    let n = c.u16()? as usize;
    let mut keys = Vec::with_capacity(n);
    for _ in 0..n {
        keys.push(c.u64()?);
    }
    if c.at != body.len() {
        return None; // trailing garbage inside a framed body
    }
    let mut cmd = Command::new(rid, keys, op, payload_len);
    cmd.batched = batched;
    Some(WalRecord { index, dot, ts, cmd })
}

/// Decode the longest valid record prefix of `buf`. Returns the records
/// plus the number of bytes consumed; anything after (a torn or corrupt
/// tail) is not replayed.
pub fn decode_records(buf: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut records = Vec::new();
    let mut at = 0;
    while at + 8 <= buf.len() {
        let len = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[at + 4..at + 8].try_into().unwrap());
        let Some(body) = buf.get(at + 8..at + 8 + len) else { break };
        if crc32(body) != crc {
            break;
        }
        let Some(rec) = decode_body(body) else { break };
        records.push(rec);
        at += 8 + len;
    }
    (records, at)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64) -> WalRecord {
        let mut cmd = Command::new(
            Rid::new(ClientId(i), i + 1),
            vec![i, i * 7 + 1],
            if i % 2 == 0 { Op::Put } else { Op::Rmw },
            (i % 100) as u32,
        );
        cmd.batched = (i % 3) as u32;
        WalRecord { index: i + 1, dot: Dot::new(ProcessId(2), i + 1), ts: 10 * i, cmd }
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // The canonical IEEE check value plus a couple of fixed points.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn records_roundtrip() {
        let mut log = Vec::new();
        let recs: Vec<WalRecord> = (0..20).map(rec).collect();
        for r in &recs {
            r.encode_into(&mut log);
        }
        let (back, consumed) = decode_records(&log);
        assert_eq!(consumed, log.len());
        assert_eq!(back, recs);
    }

    #[test]
    fn torn_tail_is_dropped_not_an_error() {
        let mut log = Vec::new();
        rec(0).encode_into(&mut log);
        let full = log.len();
        rec(1).encode_into(&mut log);
        for cut in full..log.len() {
            let (back, consumed) = decode_records(&log[..cut]);
            assert_eq!(back.len(), 1, "cut at {cut}");
            assert_eq!(consumed, full);
        }
    }

    #[test]
    fn corruption_truncates_replay_at_the_bad_record() {
        let mut log = Vec::new();
        for i in 0..5 {
            rec(i).encode_into(&mut log);
        }
        let record_len = log.len() / 5;
        // Flip one body byte of the third record: replay keeps 0..2.
        let mut bad = log.clone();
        bad[2 * record_len + 12] ^= 0x40;
        let (back, consumed) = decode_records(&bad);
        assert_eq!(back.len(), 2);
        assert_eq!(consumed, 2 * record_len);
        // A corrupted length prefix cannot over-read either.
        let mut bad = log;
        bad[0] = 0xFF;
        bad[1] = 0xFF;
        let (back, consumed) = decode_records(&bad);
        assert!(back.is_empty());
        assert_eq!(consumed, 0);
    }
}
