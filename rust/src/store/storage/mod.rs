//! Durable storage subsystem behind the [`StateMachine`] seam.
//!
//! [`Durable<S>`] wraps any [`Snapshottable`] state machine and gives the
//! replica a crash-*recovery* fault model (the rest of the stack was
//! crash-stop until now):
//!
//! - every fresh ordered execution is appended to a per-worker-slot WAL
//!   ([`wal`]) with group-commit fsync batching (`wal_fsync_batch`);
//! - every `snapshot_every` executions the store is checkpointed as a
//!   content-addressed snapshot ([`snapshot`]): hash-addressed pages in
//!   the chunk store plus a [`Manifest`], after which the WAL resets;
//! - [`Durable::recover`] rebuilds state from snapshot + WAL tail and
//!   reports what it could and could not recover, so the executor can
//!   re-seed its dedup windows and the protocol can advance its dot
//!   generator past everything the replica ever minted;
//! - [`plan_transfer`] / [`assemble`] implement manifest-diff state
//!   transfer: a restarted replica fetches only the pages it cannot
//!   produce from its own recovered state.
//!
//! `StorageMode::Memory` (the default) wires a [`NullBackend`] in, so
//! every pre-existing test and simulation is byte-identical.

pub mod backend;
pub mod snapshot;
pub mod wal;

pub use backend::{FileBackend, MemBackend, NullBackend, StorageBackend};
pub use snapshot::{chunk_hash, Manifest};
pub use wal::{crc32, decode_records, WalRecord};

use crate::core::{Command, Dot, ProcessId, Response, Rid};
use crate::store::{Snapshottable, StateMachine};
use std::collections::HashMap;

/// Durability counters, surfaced through worker stats and benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct DurableStats {
    /// WAL records appended (fresh executions logged).
    pub wal_records: u64,
    /// Checkpoints taken.
    pub snapshots: u64,
    /// Pages physically written by checkpoints.
    pub chunks_written: u64,
    /// Pages a checkpoint found already present (content-address reuse).
    pub chunks_reused: u64,
}

/// What [`Durable::recover`] managed to rebuild, and what the executor /
/// protocol layers need to resume correctly.
#[derive(Clone, Debug, Default)]
pub struct Recovery {
    /// Applied count adopted from the snapshot (0 if none).
    pub snapshot_applied: u64,
    /// WAL tail records replayed on top of the snapshot.
    pub wal_replayed: u64,
    /// Valid WAL prefix length in bytes (corruption truncates here).
    pub wal_bytes: usize,
    /// Responses recomputed during tail replay, in execution order — the
    /// executor re-inserts these into its dedup windows.
    pub replayed: Vec<(Rid, Response)>,
    /// Dedup-window blob captured by the snapshot.
    pub dedup: Vec<u8>,
    /// Per-origin dot floors (snapshot floors merged with WAL tail dots).
    pub dot_floors: Vec<(ProcessId, u64)>,
    /// Pages the manifest referenced / pages the chunk store was missing.
    pub chunks: usize,
    pub missing_chunks: usize,
}

impl Recovery {
    /// Highest recovered dot sequence minted by `origin` (0 if none).
    pub fn dot_floor(&self, origin: ProcessId) -> u64 {
        self.dot_floors
            .iter()
            .find(|(p, _)| *p == origin)
            .map_or(0, |(_, s)| *s)
    }
}

/// A [`Snapshottable`] state machine wrapped with a WAL + snapshot
/// backend. Implements [`StateMachine`] itself, so it drops into the
/// executor unchanged; `Deref` exposes the inner machine's read API.
pub struct Durable<S> {
    inner: S,
    backend: Box<dyn StorageBackend>,
    /// `false` iff the backend is the [`NullBackend`] — the wrapper then
    /// skips record encoding entirely (Memory mode costs nothing).
    active: bool,
    fsync_batch: usize,
    snapshot_every: u64,
    pending: usize,
    since_snapshot: u64,
    /// Set when a WAL fsync fails. After a failed fsync the durability
    /// of everything since the last successful sync is unknown (the
    /// kernel may have dropped the dirty pages — fsyncgate), so the slot
    /// refuses further writes instead of silently acking undurable ones.
    poisoned: bool,
    dot_floors: HashMap<ProcessId, u64>,
    stats: DurableStats,
}

impl<S: Snapshottable> Durable<S> {
    /// Wrap with a real backend: group-commit every `fsync_batch` records
    /// (clamped to ≥ 1), checkpoint every `snapshot_every` executions
    /// (0 = never).
    pub fn new(
        inner: S,
        backend: Box<dyn StorageBackend>,
        fsync_batch: usize,
        snapshot_every: u64,
    ) -> Self {
        let active = backend.is_durable();
        Durable {
            inner,
            backend,
            active,
            fsync_batch: fsync_batch.max(1),
            snapshot_every,
            pending: 0,
            since_snapshot: 0,
            poisoned: false,
            dot_floors: HashMap::new(),
            stats: DurableStats::default(),
        }
    }

    /// The Memory-mode wrapper: a no-op backend, zero overhead.
    pub fn memory(inner: S) -> Self {
        Durable::new(inner, Box::new(NullBackend), 1, 0)
    }

    /// Rebuild from a backend: snapshot pages, then the valid WAL tail
    /// (records the snapshot already captured are skipped; a torn or
    /// corrupt tail ends replay). The returned [`Recovery`] carries what
    /// the executor and protocol need to resume.
    pub fn recover(
        backend: Box<dyn StorageBackend>,
        fsync_batch: usize,
        snapshot_every: u64,
    ) -> (Self, Recovery) {
        let manifest = backend
            .read_manifest()
            .and_then(|b| Manifest::decode(&b))
            .unwrap_or_default();
        let mut missing = 0usize;
        let pages: Vec<Vec<u8>> = manifest
            .chunks
            .iter()
            .filter_map(|h| {
                let c = backend.get_chunk(*h);
                if c.is_none() {
                    missing += 1;
                }
                c
            })
            .collect();
        // A manifest with missing pages cannot be trusted: start empty
        // (state transfer will rebuild) rather than half-assembled.
        let (mut inner, base_applied) = if missing == 0 {
            (S::from_chunks(&pages, manifest.applied), manifest.applied)
        } else {
            (S::from_chunks(&[], 0), 0)
        };
        let mut dot_floors: HashMap<ProcessId, u64> = if missing == 0 {
            manifest.dot_floors.iter().copied().collect()
        } else {
            HashMap::new()
        };
        let wal_buf = backend.read_wal();
        let (records, wal_bytes) = decode_records(&wal_buf);
        let mut replayed = Vec::new();
        for rec in &records {
            let floor = dot_floors.entry(rec.dot.origin).or_insert(0);
            *floor = (*floor).max(rec.dot.seq);
            if rec.index <= base_applied {
                continue; // already reflected by the snapshot
            }
            let resp = inner.apply(&rec.cmd);
            replayed.push((rec.cmd.rid, resp));
        }
        let wal_replayed = replayed.len() as u64;
        let mut floors: Vec<(ProcessId, u64)> =
            dot_floors.iter().map(|(p, s)| (*p, *s)).collect();
        floors.sort();
        let recovery = Recovery {
            snapshot_applied: base_applied,
            wal_replayed,
            wal_bytes,
            replayed,
            dedup: if missing == 0 { manifest.dedup } else { Vec::new() },
            dot_floors: floors,
            chunks: manifest.chunks.len(),
            missing_chunks: missing,
        };
        let mut durable = Durable::new(inner, backend, fsync_batch, snapshot_every);
        durable.dot_floors = dot_floors;
        (durable, recovery)
    }

    pub fn store(&self) -> &S {
        &self.inner
    }

    pub fn store_mut(&mut self) -> &mut S {
        &mut self.inner
    }

    pub fn stats(&self) -> DurableStats {
        self.stats
    }

    pub fn backend_bytes_written(&self) -> u64 {
        self.backend.bytes_written()
    }

    pub fn backend_syncs(&self) -> u64 {
        self.backend.syncs()
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Force-sync any records still sitting in the group-commit window.
    /// A failed fsync poisons the slot (see [`Self::poisoned`]) — the
    /// pending window is *not* cleared, because those records never
    /// became durable.
    pub fn flush(&mut self) {
        if self.pending > 0 {
            if self.backend.sync_wal() {
                self.pending = 0;
            } else {
                self.poisoned = true;
            }
        }
    }

    /// Whether a WAL fsync has failed on this slot. Once poisoned, the
    /// next [`StateMachine::log_execution`] (and any checkpoint) panics:
    /// the wrapper will not acknowledge writes whose durability it
    /// cannot vouch for.
    pub fn poisoned(&self) -> bool {
        self.poisoned
    }

    fn floors_sorted(&self) -> Vec<(ProcessId, u64)> {
        let mut floors: Vec<(ProcessId, u64)> =
            self.dot_floors.iter().map(|(p, s)| (*p, *s)).collect();
        floors.sort();
        floors
    }

    /// Build a manifest of the *current* store (without persisting it) —
    /// what a donor serves to a recovering peer. Returns the manifest and
    /// its pages, page `i` addressed by `manifest.chunks[i]`.
    pub fn serve_manifest(&self, dedup: Vec<u8>) -> (Manifest, Vec<Vec<u8>>) {
        Manifest::of(&self.inner, dedup, self.floors_sorted())
    }

    /// Adopt a transferred store (and the donor's dedup blob + dot
    /// floors), then checkpoint immediately so the next restart recovers
    /// the transferred state rather than the pre-crash state.
    pub fn install(
        &mut self,
        store: S,
        dedup: &[u8],
        remote_floors: &[(ProcessId, u64)],
    ) {
        self.inner = store;
        for (p, s) in remote_floors {
            let floor = self.dot_floors.entry(*p).or_insert(0);
            *floor = (*floor).max(*s);
        }
        self.checkpoint(dedup);
    }
}

impl<S: Snapshottable> StateMachine for Durable<S> {
    fn apply(&mut self, cmd: &Command) -> Response {
        self.inner.apply(cmd)
    }

    fn digest(&self) -> u64 {
        self.inner.digest()
    }

    fn log_execution(&mut self, dot: Dot, ts: u64, cmd: &Command) {
        if !self.active {
            return;
        }
        if self.poisoned {
            panic!(
                "durable slot poisoned: a WAL fsync failed, so records \
                 acked since the last successful sync may not be on disk; \
                 refusing further writes (crash and recover instead)"
            );
        }
        let rec =
            WalRecord { index: self.inner.applied(), dot, ts, cmd: cmd.clone() };
        self.backend.append_wal(&rec.encode());
        self.stats.wal_records += 1;
        let floor = self.dot_floors.entry(dot.origin).or_insert(0);
        *floor = (*floor).max(dot.seq);
        self.pending += 1;
        if self.pending >= self.fsync_batch {
            self.flush();
        }
        self.since_snapshot += 1;
    }

    fn wants_checkpoint(&self) -> bool {
        self.active
            && self.snapshot_every > 0
            && self.since_snapshot >= self.snapshot_every
    }

    fn checkpoint(&mut self, dedup: &[u8]) {
        if !self.active {
            return;
        }
        // Records in the group-commit window must be durable before the
        // manifest can claim `applied` covers them.
        self.flush();
        if self.poisoned {
            panic!(
                "durable slot poisoned: WAL fsync failed while flushing \
                 the group-commit window; a checkpoint now would claim \
                 durability for records that may not be on disk"
            );
        }
        let (manifest, pages) =
            Manifest::of(&self.inner, dedup.to_vec(), self.floors_sorted());
        for (hash, page) in manifest.chunks.iter().zip(pages.iter()) {
            if self.backend.put_chunk(*hash, page) {
                self.stats.chunks_written += 1;
            } else {
                self.stats.chunks_reused += 1;
            }
        }
        self.backend.put_manifest(&manifest.encode());
        // The WAL is now fully captured by the snapshot (crash between
        // the manifest rename and this truncate only replays records with
        // `index <= applied`, which recovery skips).
        self.backend.truncate_wal();
        self.since_snapshot = 0;
        self.stats.snapshots += 1;
    }
}

impl<S> std::ops::Deref for Durable<S> {
    type Target = S;
    fn deref(&self) -> &S {
        &self.inner
    }
}

/// Manifest-diff transfer plan: which donor pages the recovering replica
/// can already produce locally, and which hashes it must fetch.
#[derive(Debug, Default)]
pub struct TransferPlan {
    /// Locally producible pages, by content hash.
    pub local: HashMap<u64, Vec<u8>>,
    /// Hashes to fetch from the donor (manifest order, deduplicated).
    pub need: Vec<u64>,
}

/// Diff `local` state against a donor `manifest`.
pub fn plan_transfer<S: Snapshottable>(local: &S, manifest: &Manifest) -> TransferPlan {
    let inventory: HashMap<u64, Vec<u8>> = local
        .to_chunks()
        .into_iter()
        .map(|p| (chunk_hash(&p), p))
        .collect();
    let mut need = Vec::new();
    let mut seen = std::collections::HashSet::new();
    let mut have = HashMap::new();
    for h in &manifest.chunks {
        match inventory.get(h) {
            Some(page) => {
                have.insert(*h, page.clone());
            }
            None => {
                if seen.insert(*h) {
                    need.push(*h);
                }
            }
        }
    }
    TransferPlan { local: have, need }
}

/// Assemble a store from a donor manifest once every needed page is
/// available via `lookup`; `None` if any page is still missing.
pub fn assemble<S: Snapshottable>(
    manifest: &Manifest,
    mut lookup: impl FnMut(u64) -> Option<Vec<u8>>,
) -> Option<S> {
    let pages: Option<Vec<Vec<u8>>> =
        manifest.chunks.iter().map(|h| lookup(*h)).collect();
    Some(S::from_chunks(&pages?, manifest.applied))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Op};
    use crate::store::KvStore;

    fn cmd(i: u64) -> Command {
        Command::single(Rid::new(ClientId(i % 5), i + 1), i % 37, Op::Put, 8)
    }

    fn run(d: &mut Durable<KvStore>, lo: u64, hi: u64) {
        for i in lo..hi {
            let c = cmd(i);
            let _ = d.apply(&c);
            d.log_execution(Dot::new(ProcessId(1), i + 1), 10 * i, &c);
            if d.wants_checkpoint() {
                d.checkpoint(&[]);
            }
        }
    }

    #[test]
    fn memory_mode_is_inert() {
        let mut d = Durable::memory(KvStore::new());
        run(&mut d, 0, 50);
        assert!(!d.is_active());
        assert_eq!(d.stats().wal_records, 0);
        assert_eq!(d.backend_bytes_written(), 0);
        // Deref exposes the inner store's read API.
        assert_eq!(d.applied(), 50);
    }

    #[test]
    fn recover_replays_snapshot_plus_wal_tail() {
        let backend = MemBackend::new();
        let mut d = Durable::new(KvStore::new(), Box::new(backend.clone()), 1, 16);
        run(&mut d, 0, 40); // snapshots at 16 and 32, tail of 8 in the WAL
        let want = d.digest();
        assert_eq!(d.stats().snapshots, 2);
        drop(d);
        let (r, rec) = Durable::<KvStore>::recover(Box::new(backend), 1, 16);
        assert_eq!(r.digest(), want, "byte-identical digest after recovery");
        assert_eq!(rec.snapshot_applied, 32);
        assert_eq!(rec.wal_replayed, 8);
        assert_eq!(rec.replayed.len(), 8);
        assert_eq!(r.applied(), 40);
        assert_eq!(rec.dot_floor(ProcessId(1)), 40);
        assert_eq!(rec.missing_chunks, 0);
    }

    #[test]
    fn fsync_batching_loses_only_the_group_commit_window() {
        let backend = MemBackend::new();
        let mut d = Durable::new(KvStore::new(), Box::new(backend.clone()), 8, 0);
        run(&mut d, 0, 21); // 2 full groups synced, 5 records unsynced
        assert_eq!(backend.crash(), 5);
        let (r, rec) = Durable::<KvStore>::recover(Box::new(backend), 8, 0);
        assert_eq!(rec.wal_replayed, 16);
        assert_eq!(r.applied(), 16);
        // Replaying the same 16-command prefix elsewhere agrees.
        let mut oracle = KvStore::new();
        for i in 0..16 {
            oracle.execute(&cmd(i));
        }
        assert_eq!(r.digest(), oracle.digest());
    }

    #[test]
    fn failed_fsync_poisons_the_slot_and_rejects_further_writes() {
        let backend = MemBackend::new();
        let mut d = Durable::new(KvStore::new(), Box::new(backend.clone()), 4, 0);
        run(&mut d, 0, 8); // two healthy group commits
        assert!(!d.poisoned());
        let healthy_syncs = d.backend_syncs();
        backend.fail_syncs(true);
        // The next group commit hits the failing disk: the write itself is
        // accepted (the failure only surfaces at the sync), but the slot
        // comes out poisoned and the pending window is not cleared.
        run(&mut d, 8, 12);
        assert!(d.poisoned(), "failed fsync must poison the slot");
        assert_eq!(d.backend_syncs(), healthy_syncs, "failed syncs not counted");
        // Poisoned slot refuses the next write outright.
        let c = cmd(12);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            d.log_execution(Dot::new(ProcessId(1), 13), 120, &c);
        }));
        assert!(err.is_err(), "log_execution on a poisoned slot must panic");
        // ... and a checkpoint must not claim durability either.
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            d.checkpoint(&[]);
        }));
        assert!(err.is_err(), "checkpoint on a poisoned slot must panic");
        // The disk never saw the unsynced tail: recovery replays only the
        // records covered by successful syncs.
        drop(d);
        backend.crash();
        let (r, rec) = Durable::<KvStore>::recover(Box::new(backend), 4, 0);
        assert_eq!(rec.wal_replayed, 8);
        assert_eq!(r.applied(), 8);
    }

    #[test]
    fn corrupt_wal_record_truncates_replay() {
        let backend = MemBackend::new();
        let mut d = Durable::new(KvStore::new(), Box::new(backend.clone()), 1, 0);
        run(&mut d, 0, 10);
        let record_len = backend.synced_wal_len() / 10;
        backend.corrupt_synced_wal(4 * record_len + 9); // 5th record's body
        let (r, rec) = Durable::<KvStore>::recover(Box::new(backend), 1, 0);
        assert_eq!(rec.wal_replayed, 4);
        assert_eq!(rec.wal_bytes, 4 * record_len);
        assert_eq!(r.applied(), 4);
    }

    #[test]
    fn checkpoint_reuses_unchanged_pages() {
        let backend = MemBackend::new();
        let mut d = Durable::new(KvStore::new(), Box::new(backend), 1, 0);
        // Two checkpoints over an unchanged key set: every page of the
        // second is a content-address hit except the ones actually dirtied.
        for i in 0..200 {
            let c = Command::single(Rid::new(ClientId(0), i + 1), i, Op::Put, 4);
            let _ = d.apply(&c);
            d.log_execution(Dot::new(ProcessId(0), i + 1), i, &c);
        }
        d.checkpoint(&[]);
        let first = d.stats();
        assert!(first.chunks_written >= 3);
        assert_eq!(first.chunks_reused, 0);
        let c = Command::single(Rid::new(ClientId(0), 201), 7, Op::Put, 4);
        let _ = d.apply(&c);
        d.log_execution(Dot::new(ProcessId(0), 201), 999, &c);
        d.checkpoint(&[]);
        let second = d.stats();
        assert_eq!(second.chunks_written, first.chunks_written + 1);
        assert_eq!(second.chunks_reused, first.chunks_written - 1);
    }

    #[test]
    fn transfer_plan_fetches_only_the_diff_and_assembles_identically() {
        // Donor: 300 commands. Recovering replica: the first 250 of the
        // same sequence — most pages match, only the diff is fetched.
        let mut donor = KvStore::new();
        let mut local = KvStore::new();
        for i in 0..300u64 {
            let c = Command::single(Rid::new(ClientId(0), i + 1), i, Op::Put, 4);
            donor.execute(&c);
            if i < 250 {
                local.execute(&c);
            }
        }
        let (manifest, pages) = Manifest::of(&donor, vec![7, 7], vec![]);
        let plan = plan_transfer(&local, &manifest);
        assert!(!plan.need.is_empty(), "divergent pages must be fetched");
        assert!(
            plan.need.len() < manifest.chunks.len(),
            "matching pages must NOT be fetched ({} of {})",
            plan.need.len(),
            manifest.chunks.len()
        );
        let donor_pages: HashMap<u64, Vec<u8>> = manifest
            .chunks
            .iter()
            .copied()
            .zip(pages.iter().cloned())
            .collect();
        let assembled: KvStore = assemble(&manifest, |h| {
            plan.local
                .get(&h)
                .cloned()
                .or_else(|| plan.need.contains(&h).then(|| donor_pages[&h].clone()))
        })
        .expect("all pages available");
        assert_eq!(assembled.digest(), donor.digest());
        assert_eq!(assembled.applied(), donor.applied());
        // A page that never arrives fails assembly instead of building a
        // silently-wrong store.
        let partial: Option<KvStore> =
            assemble(&manifest, |h| plan.local.get(&h).cloned());
        assert!(partial.is_none());
    }
}
