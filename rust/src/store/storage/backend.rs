//! Pluggable durability backends.
//!
//! - [`NullBackend`]: the `StorageMode::Memory` default — every call is a
//!   no-op, so the durable wrapper costs nothing and all pre-existing
//!   equivalence tests see byte-identical behavior.
//! - [`MemBackend`]: a deterministic in-memory backend for the simulator.
//!   It models group-commit loss faithfully: appends buffer in an
//!   *unsynced* tail until `sync_wal`, and a simulated crash discards the
//!   unsynced tail — exactly what a real fsync-batched WAL loses on power
//!   failure. Handles are cheap clones over shared state so the sim can
//!   keep a backend across a crash/restart of its process.
//! - [`FileBackend`]: real files + fsync for the TCP runtime
//!   (`StorageMode::Disk`): one append-only WAL per worker slot, a
//!   content-addressed chunk directory shared across snapshots, and an
//!   atomically-renamed manifest.

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Storage operations the [`super::Durable`] wrapper needs. WAL appends
/// are durable only after `sync_wal` (the group-commit point); chunk and
/// manifest writes are synchronous (the snapshot path is not hot).
pub trait StorageBackend: Send {
    /// Append one framed WAL record (durable only after [`Self::sync_wal`]).
    fn append_wal(&mut self, record: &[u8]);
    /// Make all appended records durable (fsync; the group-commit point).
    /// Returns `false` when the sync **failed** — after a failed fsync
    /// the kernel may have dropped the dirty pages, so the durability of
    /// everything appended since the last successful sync is unknown
    /// (the fsyncgate lesson: retrying the fsync cannot bring it back).
    /// The [`super::Durable`] wrapper reacts by poisoning the slot.
    fn sync_wal(&mut self) -> bool;
    /// All durable WAL bytes, in append order.
    fn read_wal(&self) -> Vec<u8>;
    /// Drop the WAL after a snapshot captured its effects.
    fn truncate_wal(&mut self);
    /// Store a content-addressed page; returns `true` when the hash was
    /// new (bytes physically written) — unchanged pages are free.
    fn put_chunk(&mut self, hash: u64, bytes: &[u8]) -> bool;
    fn get_chunk(&self, hash: u64) -> Option<Vec<u8>>;
    /// Atomically install the snapshot manifest.
    fn put_manifest(&mut self, bytes: &[u8]);
    fn read_manifest(&self) -> Option<Vec<u8>>;
    /// Bytes physically written so far (write-amplification accounting).
    fn bytes_written(&self) -> u64;
    /// fsyncs issued so far.
    fn syncs(&self) -> u64;
    /// Is this a real backend? `false` only for [`NullBackend`], letting
    /// the wrapper skip record encoding entirely in `Memory` mode.
    fn is_durable(&self) -> bool {
        true
    }
}

/// No-op backend: `StorageMode::Memory`.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullBackend;

impl StorageBackend for NullBackend {
    fn append_wal(&mut self, _record: &[u8]) {}
    fn sync_wal(&mut self) -> bool {
        true
    }
    fn read_wal(&self) -> Vec<u8> {
        Vec::new()
    }
    fn truncate_wal(&mut self) {}
    fn put_chunk(&mut self, _hash: u64, _bytes: &[u8]) -> bool {
        false
    }
    fn get_chunk(&self, _hash: u64) -> Option<Vec<u8>> {
        None
    }
    fn put_manifest(&mut self, _bytes: &[u8]) {}
    fn read_manifest(&self) -> Option<Vec<u8>> {
        None
    }
    fn bytes_written(&self) -> u64 {
        0
    }
    fn syncs(&self) -> u64 {
        0
    }
    fn is_durable(&self) -> bool {
        false
    }
}

#[derive(Debug, Default)]
struct MemInner {
    synced_wal: Vec<u8>,
    unsynced_wal: Vec<u8>,
    unsynced_records: u64,
    chunks: HashMap<u64, Vec<u8>>,
    manifest: Option<Vec<u8>>,
    bytes_written: u64,
    syncs: u64,
    /// Fault-injection knob: while set, `sync_wal` fails (returns
    /// `false`) and the tail stays unsynced — exactly what a failed
    /// fsync means for the data's durability.
    fail_syncs: bool,
}

/// Deterministic in-memory backend; clones share state (sim keeps one
/// handle per process across crash/restart).
#[derive(Clone, Debug, Default)]
pub struct MemBackend {
    inner: Arc<Mutex<MemInner>>,
}

impl MemBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Simulate a crash at this instant: the unsynced WAL tail is lost
    /// (exactly the group-commit window). Returns how many records the
    /// crash discarded, for the recovery audit.
    pub fn crash(&self) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let lost = g.unsynced_records;
        g.unsynced_wal.clear();
        g.unsynced_records = 0;
        lost
    }

    /// Test knob: flip one byte of the *synced* WAL, modelling media
    /// corruption — replay must truncate at the damaged record.
    pub fn corrupt_synced_wal(&self, at: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(b) = g.synced_wal.get_mut(at) {
            *b ^= 0x01;
        }
    }

    pub fn synced_wal_len(&self) -> usize {
        self.inner.lock().unwrap().synced_wal.len()
    }

    /// Test knob: make every subsequent `sync_wal` fail (model a dying
    /// disk / full filesystem). The unsynced tail stays unsynced —
    /// retrying an fsync after a failure cannot make the lost dirty
    /// pages durable.
    pub fn fail_syncs(&self, fail: bool) {
        self.inner.lock().unwrap().fail_syncs = fail;
    }
}

impl StorageBackend for MemBackend {
    fn append_wal(&mut self, record: &[u8]) {
        let mut g = self.inner.lock().unwrap();
        g.unsynced_wal.extend_from_slice(record);
        g.unsynced_records += 1;
    }
    fn sync_wal(&mut self) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.fail_syncs {
            return false;
        }
        if g.unsynced_wal.is_empty() {
            return true;
        }
        let tail = std::mem::take(&mut g.unsynced_wal);
        g.bytes_written += tail.len() as u64;
        g.synced_wal.extend_from_slice(&tail);
        g.unsynced_records = 0;
        g.syncs += 1;
        true
    }
    fn read_wal(&self) -> Vec<u8> {
        self.inner.lock().unwrap().synced_wal.clone()
    }
    fn truncate_wal(&mut self) {
        let mut g = self.inner.lock().unwrap();
        g.synced_wal.clear();
        g.unsynced_wal.clear();
        g.unsynced_records = 0;
    }
    fn put_chunk(&mut self, hash: u64, bytes: &[u8]) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.chunks.contains_key(&hash) {
            return false;
        }
        g.bytes_written += bytes.len() as u64;
        g.chunks.insert(hash, bytes.to_vec());
        true
    }
    fn get_chunk(&self, hash: u64) -> Option<Vec<u8>> {
        self.inner.lock().unwrap().chunks.get(&hash).cloned()
    }
    fn put_manifest(&mut self, bytes: &[u8]) {
        let mut g = self.inner.lock().unwrap();
        g.bytes_written += bytes.len() as u64;
        g.manifest = Some(bytes.to_vec());
        g.syncs += 1;
    }
    fn read_manifest(&self) -> Option<Vec<u8>> {
        self.inner.lock().unwrap().manifest.clone()
    }
    fn bytes_written(&self) -> u64 {
        self.inner.lock().unwrap().bytes_written
    }
    fn syncs(&self) -> u64 {
        self.inner.lock().unwrap().syncs
    }
}

/// Real-file backend rooted at one directory per worker slot:
/// `wal.log` (append-only), `MANIFEST` (atomic rename), and
/// `chunks/<hash:016x>.page` (content-addressed, shared across
/// snapshots).
#[derive(Debug)]
pub struct FileBackend {
    dir: PathBuf,
    wal: File,
    bytes_written: u64,
    syncs: u64,
}

impl FileBackend {
    pub fn open(dir: &Path) -> std::io::Result<Self> {
        fs::create_dir_all(dir.join("chunks"))?;
        let wal = OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join("wal.log"))?;
        Ok(FileBackend { dir: dir.to_path_buf(), wal, bytes_written: 0, syncs: 0 })
    }

    fn chunk_path(&self, hash: u64) -> PathBuf {
        self.dir.join("chunks").join(format!("{hash:016x}.page"))
    }
}

impl StorageBackend for FileBackend {
    fn append_wal(&mut self, record: &[u8]) {
        self.wal.write_all(record).expect("WAL append failed");
        self.bytes_written += record.len() as u64;
    }
    fn sync_wal(&mut self) -> bool {
        // A failed fsync is surfaced, not unwrapped: the caller decides
        // (the `Durable` wrapper poisons the slot — acking writes whose
        // dirty pages the kernel may have dropped would be a lie).
        let ok = self.wal.sync_data().is_ok();
        if ok {
            self.syncs += 1;
        }
        ok
    }
    fn read_wal(&self) -> Vec<u8> {
        fs::read(self.dir.join("wal.log")).unwrap_or_default()
    }
    fn truncate_wal(&mut self) {
        self.wal.set_len(0).expect("WAL truncate failed");
        self.wal.sync_data().expect("WAL fsync failed");
        self.syncs += 1;
    }
    fn put_chunk(&mut self, hash: u64, bytes: &[u8]) -> bool {
        let path = self.chunk_path(hash);
        if path.exists() {
            return false;
        }
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, bytes).expect("chunk write failed");
        fs::rename(&tmp, &path).expect("chunk rename failed");
        self.bytes_written += bytes.len() as u64;
        true
    }
    fn get_chunk(&self, hash: u64) -> Option<Vec<u8>> {
        fs::read(self.chunk_path(hash)).ok()
    }
    fn put_manifest(&mut self, bytes: &[u8]) {
        let path = self.dir.join("MANIFEST");
        let tmp = self.dir.join("MANIFEST.tmp");
        let mut f = File::create(&tmp).expect("manifest create failed");
        f.write_all(bytes).expect("manifest write failed");
        f.sync_data().expect("manifest fsync failed");
        drop(f);
        fs::rename(&tmp, &path).expect("manifest rename failed");
        self.bytes_written += bytes.len() as u64;
        self.syncs += 1;
    }
    fn read_manifest(&self) -> Option<Vec<u8>> {
        fs::read(self.dir.join("MANIFEST")).ok()
    }
    fn bytes_written(&self) -> u64 {
        self.bytes_written
    }
    fn syncs(&self) -> u64 {
        self.syncs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_backend_models_group_commit_loss() {
        let mut b = MemBackend::new();
        b.append_wal(b"aaaa");
        b.sync_wal();
        b.append_wal(b"bbbb");
        b.append_wal(b"cccc");
        assert_eq!(b.read_wal(), b"aaaa", "unsynced tail is not yet durable");
        assert_eq!(b.crash(), 2, "crash loses exactly the unsynced records");
        assert_eq!(b.read_wal(), b"aaaa");
        // A clone shares state — the sim's registry handle sees the same log.
        let other = b.clone();
        b.append_wal(b"dddd");
        b.sync_wal();
        assert_eq!(other.read_wal(), b"aaaadddd");
        assert_eq!(other.syncs(), 2);
    }

    #[test]
    fn mem_backend_chunks_are_content_addressed() {
        let mut b = MemBackend::new();
        assert!(b.put_chunk(7, b"page"));
        assert!(!b.put_chunk(7, b"page"), "second put of same hash is free");
        let w = b.bytes_written();
        b.put_chunk(7, b"page");
        assert_eq!(b.bytes_written(), w);
        assert_eq!(b.get_chunk(7).as_deref(), Some(&b"page"[..]));
        assert_eq!(b.get_chunk(8), None);
    }

    #[test]
    fn null_backend_is_inert() {
        let mut b = NullBackend;
        b.append_wal(b"x");
        b.sync_wal();
        assert!(b.read_wal().is_empty());
        assert!(!b.is_durable());
        assert_eq!(b.bytes_written(), 0);
    }

    #[test]
    fn file_backend_roundtrips_wal_chunks_and_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "tempo-storage-test-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        {
            let mut b = FileBackend::open(&dir).unwrap();
            b.append_wal(b"rec1");
            b.append_wal(b"rec2");
            b.sync_wal();
            assert!(b.put_chunk(0xabc, b"chunk-bytes"));
            assert!(!b.put_chunk(0xabc, b"chunk-bytes"));
            b.put_manifest(b"manifest-bytes");
        }
        // Reopen: everything must survive the process "restart".
        let mut b = FileBackend::open(&dir).unwrap();
        assert_eq!(b.read_wal(), b"rec1rec2");
        assert_eq!(b.get_chunk(0xabc).as_deref(), Some(&b"chunk-bytes"[..]));
        assert_eq!(b.read_manifest().as_deref(), Some(&b"manifest-bytes"[..]));
        b.truncate_wal();
        assert!(b.read_wal().is_empty());
        b.append_wal(b"rec3");
        b.sync_wal();
        assert_eq!(b.read_wal(), b"rec3");
        let _ = fs::remove_dir_all(&dir);
    }
}
