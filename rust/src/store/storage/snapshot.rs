//! Content-addressed snapshots: the store is chunked into hash-addressed
//! pages ([`crate::store::Snapshottable::to_chunks`]), and a snapshot is a
//! [`Manifest`] — the ordered list of page hashes, rolled up by the
//! existing [`crate::store::merkle_root`] machinery, plus the executor's
//! dedup-window blob and the per-origin dot floors.
//!
//! Because pages are addressed by content (FNV-1a 64 of the bytes), two
//! replicas diff state by exchanging manifests: a restarted replica
//! fetches only the hashes it cannot produce from its own recovered
//! state, and unchanged pages are shared across snapshots in the chunk
//! store for free.

use crate::core::ProcessId;
use crate::store::{merkle_root, Snapshottable};

/// FNV-1a 64 content address of a chunk — the same hash family the store
/// digest and Merkle roll-up use.
pub fn chunk_hash(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A snapshot: everything needed to rebuild a replica's executor state
/// (given the chunks the hashes address) and to resume the protocol.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    /// Commands applied at the moment of the snapshot — WAL records with
    /// `index <= applied` are already reflected and skipped on replay.
    pub applied: u64,
    /// Content hashes of the store's pages, in page order.
    pub chunks: Vec<u64>,
    /// Serialized executor dedup windows (exactly-once across restart).
    pub dedup: Vec<u8>,
    /// Highest dot sequence seen per origin, so a restarted replica can
    /// advance its [`crate::core::DotGen`] past everything it ever minted.
    pub dot_floors: Vec<(ProcessId, u64)>,
}

impl Manifest {
    /// Merkle root over the page hashes: equal roots mean equal page
    /// vectors, an unequal root localizes the diff to specific pages.
    pub fn root(&self) -> u64 {
        merkle_root(&self.chunks)
    }

    /// Build a manifest for `sm`'s current state (chunks must be stored
    /// separately, keyed by the returned hashes).
    pub fn of<S: Snapshottable>(
        sm: &S,
        dedup: Vec<u8>,
        dot_floors: Vec<(ProcessId, u64)>,
    ) -> (Manifest, Vec<Vec<u8>>) {
        let pages = sm.to_chunks();
        let chunks = pages.iter().map(|p| chunk_hash(p)).collect();
        (Manifest { applied: sm.applied(), chunks, dedup, dot_floors }, pages)
    }

    /// Serialize (LE): `applied u64, nchunks u32, hash u64 each,
    /// nfloors u16, (origin u32, seq u64) each, dedup_len u32, dedup`.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            8 + 4 + 8 * self.chunks.len() + 2 + 12 * self.dot_floors.len() + 4
                + self.dedup.len(),
        );
        out.extend_from_slice(&self.applied.to_le_bytes());
        out.extend_from_slice(&(self.chunks.len() as u32).to_le_bytes());
        for h in &self.chunks {
            out.extend_from_slice(&h.to_le_bytes());
        }
        out.extend_from_slice(&(self.dot_floors.len() as u16).to_le_bytes());
        for (p, seq) in &self.dot_floors {
            out.extend_from_slice(&p.0.to_le_bytes());
            out.extend_from_slice(&seq.to_le_bytes());
        }
        out.extend_from_slice(&(self.dedup.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.dedup);
        out
    }

    /// Parse a serialized manifest; `None` on any truncation or trailing
    /// garbage (a corrupt manifest means recovery starts from empty).
    pub fn decode(buf: &[u8]) -> Option<Manifest> {
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Option<&[u8]> {
            let s = buf.get(*at..*at + n)?;
            *at += n;
            Some(s)
        };
        let applied = u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
        let n = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
        let mut chunks = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            chunks.push(u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap()));
        }
        let f = u16::from_le_bytes(take(&mut at, 2)?.try_into().unwrap()) as usize;
        let mut dot_floors = Vec::with_capacity(f);
        for _ in 0..f {
            let p = ProcessId(u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()));
            let s = u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
            dot_floors.push((p, s));
        }
        let d = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
        let dedup = take(&mut at, d)?.to_vec();
        if at != buf.len() {
            return None;
        }
        Some(Manifest { applied, chunks, dedup, dot_floors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Command, Op, Rid};
    use crate::store::KvStore;

    fn store(n: u64) -> KvStore {
        let mut s = KvStore::new();
        for i in 0..n {
            s.execute(&Command::single(
                Rid::new(ClientId(i), 1),
                i % 97,
                Op::Put,
                (i % 11) as u32,
            ));
        }
        s
    }

    #[test]
    fn manifest_roundtrip_and_root() {
        let s = store(300);
        let (m, pages) = Manifest::of(
            &s,
            vec![1, 2, 3],
            vec![(ProcessId(0), 7), (ProcessId(2), 19)],
        );
        assert_eq!(m.applied, 300);
        assert_eq!(m.chunks.len(), pages.len());
        assert_eq!(Manifest::decode(&m.encode()), Some(m.clone()));
        assert_eq!(m.root(), merkle_root(&m.chunks));
        // Equal stores produce equal manifest roots; a divergent store
        // does not.
        let (m2, _) = Manifest::of(&store(300), vec![1, 2, 3], vec![]);
        assert_eq!(m.root(), m2.root());
        let (m3, _) = Manifest::of(&store(301), vec![], vec![]);
        assert_ne!(m.root(), m3.root());
    }

    #[test]
    fn manifest_decode_rejects_truncation_and_trailing_garbage() {
        let (m, _) = Manifest::of(&store(100), vec![9; 40], vec![(ProcessId(1), 5)]);
        let enc = m.encode();
        for cut in 0..enc.len() {
            assert_eq!(Manifest::decode(&enc[..cut]), None, "cut {cut}");
        }
        let mut padded = enc;
        padded.push(0);
        assert_eq!(Manifest::decode(&padded), None);
    }
}
