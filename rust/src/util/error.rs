//! Minimal error handling (the offline registry ships no `anyhow`): an
//! owned-message error with context chaining, covering the subset this
//! crate needs — `Result`, `bail!`, and `Context::with_context`.

use std::fmt;

/// An error carrying a human-readable message (with any context chain
/// already folded into the string).
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Return early with a formatted [`Error`].
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}
pub(crate) use bail;

/// Attach context to the error side of a `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn may_fail(fail: bool) -> Result<u32> {
        if fail {
            bail!("failed with code {}", 7);
        }
        Ok(1)
    }

    #[test]
    fn bail_formats_and_context_chains() {
        assert_eq!(may_fail(false).unwrap(), 1);
        let e = may_fail(true).unwrap_err();
        assert_eq!(e.to_string(), "failed with code 7");
        let chained: Result<u32> = may_fail(true).with_context(|| "outer");
        assert_eq!(chained.unwrap_err().to_string(), "outer: failed with code 7");
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e: Error = io.into();
        assert!(e.to_string().contains("boom"));
    }
}
