//! Minimal property-based testing harness.
//!
//! The offline registry does not ship `proptest`, so this module provides
//! the subset we need: seeded case generation, a configurable number of
//! iterations, and failure reports that include the seed so a failing case
//! can be replayed deterministically with `PROP_SEED=<seed>`.

use super::rng::Rng;

/// Number of cases per property; override with env `PROP_CASES`.
pub fn cases() -> u64 {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// Base seed; override with env `PROP_SEED` to replay a failure.
pub fn base_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xDEFA117)
}

/// Run `prop` on `cases()` generated inputs. `gen` receives a seeded RNG.
/// On failure the panic message carries the per-case seed.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = base_seed();
    let n = if std::env::var("PROP_SEED").is_ok() { 1 } else { cases() };
    for i in 0..n {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (case {i}, PROP_SEED={seed}):\n  {msg}\n  input: {input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property also drives the RNG (for random
/// schedules where the input is the seed itself).
pub fn forall_seeds(name: &str, mut prop: impl FnMut(u64) -> Result<(), String>) {
    let base = base_seed();
    let n = if std::env::var("PROP_SEED").is_ok() { 1 } else { cases() };
    for i in 0..n {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(seed) {
            panic!("property '{name}' failed (case {i}, PROP_SEED={seed}):\n  {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", |r| r.gen_range(100), |x| {
            assert!(*x < 100);
            Ok(())
        });
        forall_seeds("seeded", |_| {
            count += 1;
            Ok(())
        });
        let _ = count;
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn failing_property_reports_seed() {
        forall("failing", |r| r.gen_range(10), |x| {
            if *x < 10 {
                Err("always fails".to_string())
            } else {
                Ok(())
            }
        });
    }
}
