//! Self-contained utilities (the offline registry lacks `rand`/`proptest`).

pub mod prop;
pub mod rng;

pub use rng::{Rng, Zipf};
