//! Self-contained utilities (the offline registry lacks `rand`/`proptest`
//! and `anyhow`).

pub mod error;
pub mod prop;
pub mod rng;

pub use rng::{Rng, Zipf};
