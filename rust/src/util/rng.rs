//! Deterministic PRNG and samplers.
//!
//! The offline registry does not ship `rand`, so we implement
//! xoshiro256** (Blackman & Vigna) seeded via SplitMix64 — the standard
//! construction — plus the samplers the workloads need (uniform, zipf,
//! shuffle).

/// xoshiro256** PRNG. Deterministic, fast, good statistical quality.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 seeding, as recommended by the xoshiro authors.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Lemire's nearly-divisionless method.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn gen_between(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct elements from `xs` (partial Fisher–Yates).
    pub fn sample<T: Clone>(&mut self, xs: &[T], k: usize) -> Vec<T> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        let k = k.min(xs.len());
        for i in 0..k {
            let j = self.gen_between(i as u64, xs.len() as u64) as usize;
            idx.swap(i, j);
        }
        idx[..k].iter().map(|&i| xs[i].clone()).collect()
    }
}

/// Zipf(θ) sampler over `[0, n)` using the Gray et al. (SIGMOD'94)
/// computation, the same construction YCSB uses. θ = 0 is uniform;
/// the paper uses θ ∈ {0.5, 0.7}.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        assert!((0.0..1.0).contains(&theta), "theta in [0,1): {theta}");
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Self { n, theta, alpha, zetan, eta, zeta2 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Exact for n <= 10^6; for larger n use the Euler–Maclaurin
        // approximation (error < 1e-9 for theta < 1).
        if n <= 1_000_000 {
            (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
        } else {
            let head: f64 = (1..=1_000_000u64).map(|i| 1.0 / (i as f64).powf(theta)).sum();
            let a = 1_000_000f64;
            let b = n as f64;
            head + (b.powf(1.0 - theta) - a.powf(1.0 - theta)) / (1.0 - theta)
                + 0.5 * (1.0 / b.powf(theta) - 1.0 / a.powf(theta))
        }
    }

    /// Draw a rank in `[0, n)`; rank 0 is the most popular key.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.gen_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let k = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        k.min(self.n - 1)
    }

    pub fn theta(&self) -> f64 {
        self.theta
    }

    #[allow(dead_code)]
    fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 17, 1 << 40] {
            for _ in 0..1000 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_range_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.gen_range(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn gen_bool_probability() {
        let mut r = Rng::new(2);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.02)).count();
        assert!((1_500..2_500).contains(&hits), "2% conflicts ~ {hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct() {
        let mut r = Rng::new(4);
        let xs: Vec<u32> = (0..20).collect();
        let s = r.sample(&xs, 5);
        assert_eq!(s.len(), 5);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 5);
    }

    #[test]
    fn zipf_skew_increases_with_theta() {
        let mut r = Rng::new(5);
        let hot_fraction = |theta: f64, r: &mut Rng| {
            let z = Zipf::new(1_000_000, theta);
            let hits = (0..50_000).filter(|_| z.sample(r) < 100).count();
            hits as f64 / 50_000.0
        };
        let f05 = hot_fraction(0.5, &mut r);
        let f07 = hot_fraction(0.7, &mut r);
        assert!(f07 > f05, "zipf 0.7 ({f07}) should be hotter than 0.5 ({f05})");
        assert!(f05 > 0.001);
    }

    #[test]
    fn zipf_stays_in_range() {
        let mut r = Rng::new(6);
        let z = Zipf::new(1000, 0.7);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 1000);
        }
    }
}
