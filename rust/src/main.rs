//! `tempo` — CLI launcher for the Tempo reproduction.
//!
//! Subcommands:
//!   sim      run a protocol under the wide-area simulator and print metrics
//!   cluster  run one real Tempo node over TCP (deployable: one process per
//!            replica, full mesh given by --addrs)
//!   bench    list the paper-figure benchmarks and how to run them
//!
//! Examples:
//!   tempo sim --protocol tempo --r 5 --f 1 --conflicts 0.02 --clients 64
//!   tempo sim --protocol janus --r 3 --f 1 --shards 4 --ycsb 0.7,0.5
//!   tempo cluster --id 0 --r 3 --addrs 10.0.0.1:7000,10.0.0.2:7000,10.0.0.3:7000

use std::collections::HashMap;
use tempo::bench_util::{latency_opts, throughput_opts};
use tempo::core::{Config, ProcessId};
use tempo::protocol::caesar::Caesar;
use tempo::protocol::common::Sharded;
use tempo::protocol::depsmr::{Atlas, EPaxos, Janus};
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, SimOpts, Topology};
use tempo::workload::{ConflictWorkload, Workload, YcsbWorkload};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let value = args.get(i + 1).cloned().unwrap_or_default();
            flags.insert(name.to_string(), value);
            i += 2;
        } else {
            i += 1;
        }
    }
    flags
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, name: &str, default: T) -> T {
    flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn run_sim<P: Protocol, W: Workload>(config: Config, opts: SimOpts, workload: W) {
    let result = run::<P, W>(config, opts, workload);
    let t = result.metrics.latency.tail_summary();
    println!("protocol      : {}", P::name());
    println!("ops completed : {}", result.metrics.ops);
    println!("throughput    : {:.1} kops/s", result.metrics.throughput_ops_s() / 1e3);
    println!("latency       : {t}");
    println!(
        "paths         : fast={} slow={} recoveries={}",
        result.metrics.counters.fast_path,
        result.metrics.counters.slow_path,
        result.metrics.counters.recoveries
    );
    for (site, h) in &result.metrics.site_latency {
        println!("site {site}        : mean {:.1} ms", h.mean() / 1e3);
    }
    if !result.metrics.utilization.is_empty() {
        let (cpu, net_in, net_out) = result.metrics.mean_utilization();
        println!("utilization   : cpu {cpu:.0}% in {net_in:.0}% out {net_out:.0}%");
    }
}

fn sim_command(args: &[String]) {
    let flags = parse_flags(args);
    let protocol = flags.get("protocol").cloned().unwrap_or_else(|| "tempo".into());
    let r: usize = flag(&flags, "r", 5);
    let f: usize = flag(&flags, "f", 1);
    let shards: u32 = flag(&flags, "shards", 1);
    let workers: usize = flag(&flags, "workers", 1);
    let clients: usize = flag(&flags, "clients", 64);
    let duration_s: u64 = flag(&flags, "duration", 10);
    let seed: u64 = flag(&flags, "seed", 1);
    let cluster_mode = flags.contains_key("cluster-mode");

    let config = Config::new(r, f).with_shards(shards).with_workers(workers);
    let topology = match r {
        3 => Topology::ec2_three(),
        5 => Topology::ec2(),
        n => Topology::uniform(n, 50),
    };
    let mut opts = if cluster_mode {
        throughput_opts(topology, clients, seed)
    } else {
        latency_opts(topology, clients, seed)
    };
    opts.duration_us = duration_s * 1_000_000;

    // Workload: --ycsb zipf,writes takes precedence over --conflicts.
    enum W {
        Conflict(ConflictWorkload),
        Ycsb(YcsbWorkload),
    }
    let workload = if let Some(y) = flags.get("ycsb") {
        let parts: Vec<f64> = y.split(',').filter_map(|s| s.parse().ok()).collect();
        let (zipf, writes) =
            (parts.first().copied().unwrap_or(0.5), parts.get(1).copied().unwrap_or(0.5));
        W::Ycsb(YcsbWorkload::new(100_000 * shards as u64, zipf, writes))
    } else {
        let conflicts: f64 = flag(&flags, "conflicts", 0.02);
        let payload: u32 = flag(&flags, "payload", 100);
        W::Conflict(ConflictWorkload::new(conflicts, payload))
    };

    // --workers > 1 runs the protocol behind the per-key worker router
    // (protocol::common::shard). Commands must then live inside one worker
    // slot — single-key workloads always do; a spanning YCSB transaction
    // fails loudly at submit.
    macro_rules! dispatch {
        ($p:ty) => {
            if workers > 1 {
                match workload {
                    W::Conflict(w) => run_sim::<Sharded<$p>, _>(config, opts, w),
                    W::Ycsb(w) => run_sim::<Sharded<$p>, _>(config, opts, w),
                }
            } else {
                match workload {
                    W::Conflict(w) => run_sim::<$p, _>(config, opts, w),
                    W::Ycsb(w) => run_sim::<$p, _>(config, opts, w),
                }
            }
        };
    }
    match protocol.as_str() {
        "tempo" => dispatch!(Tempo),
        "atlas" => dispatch!(Atlas),
        "epaxos" => dispatch!(EPaxos),
        "janus" => dispatch!(Janus),
        "fpaxos" => dispatch!(FPaxos),
        "caesar" => dispatch!(Caesar),
        other => {
            eprintln!("unknown protocol '{other}' (tempo|atlas|epaxos|janus|fpaxos|caesar)");
            std::process::exit(2);
        }
    }
}

fn cluster_command(args: &[String]) {
    let flags = parse_flags(args);
    let id: u32 = flag(&flags, "id", 0);
    let r: usize = flag(&flags, "r", 3);
    let f: usize = flag(&flags, "f", 1);
    let addrs: Vec<String> =
        flags.get("addrs").map(|a| a.split(',').map(String::from).collect()).unwrap_or_default();
    if addrs.len() != r {
        eprintln!("--addrs must list exactly r={r} host:port entries");
        std::process::exit(2);
    }
    let workers: usize = flag(&flags, "workers", 1);
    let config = Config::new(r, f)
        .with_tick_interval_us(flag(&flags, "tick-us", 1_000))
        .with_workers(workers);
    println!(
        "tempo node {id}: r={r} f={f} workers={workers} listening on {}",
        addrs[id as usize]
    );
    match tempo::net::start_node(ProcessId(id), config, addrs) {
        Ok(_node) => {
            println!("node up; serving until killed (Ctrl-C)");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("failed to start node: {e:#}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("sim") => sim_command(&args[1..]),
        Some("cluster") => cluster_command(&args[1..]),
        Some("bench") => {
            println!("paper benchmarks (each prints the corresponding table/figure):");
            for b in [
                "table1_fastpath",
                "fig5_fairness",
                "fig6_tail_latency",
                "fig7_load_contention",
                "fig8_batching",
                "fig9_partial_replication",
                "ablation",
                "microbench",
            ] {
                println!("  cargo bench --bench {b}");
            }
        }
        _ => {
            println!("tempo — Efficient Replication via Timestamp Stability (EuroSys'21)");
            println!("usage: tempo <sim|cluster|bench> [--flags]   (see src/main.rs docs)");
        }
    }
}
