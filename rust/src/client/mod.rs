//! Client service API (paper §6.1): sessions and rifl-style request ids.
//!
//! The paper's framework serves real clients — a command is submitted at a
//! coordinator replica, executed at timestamp stability, and its result is
//! returned to the issuing client. This module is the client half of that
//! contract:
//!
//! - a [`Session`] holds a [`ClientId`] and allocates [`Rid`]s — request
//!   ids `Rid(client, seq)` with a per-session monotone sequence — and
//!   builds [`Command`]s carrying them;
//! - `Protocol::submit(cmd, time)` renames the command internally to a
//!   `Dot` (callers never pre-allocate dots);
//! - the replica's `executor::Executor` applies the command at execution
//!   time and emits `Action::Reply { rid, response }` at the command's
//!   coordinator only, which the runtimes route back to the session (in
//!   the TCP runtime as a `ClientReply` frame, docs/WIRE.md tag 18).
//!
//! The simulator drives one `Session` per closed-loop client; the TCP
//! runtime wraps one in `net::TcpClient` for real request/response
//! traffic over sockets.

#![warn(missing_docs)]

use crate::core::{ClientId, Command, Key, Op, Rid};
use crate::util::error::Error;

/// Prefix of every busy-shed error a client can observe: a node whose
/// per-session in-flight window (`Config::max_inflight_per_session`) is
/// full sheds the submit at the edge with a `ClientBusy` frame
/// (docs/WIRE.md tag 25), and `net::TcpClient` surfaces it as an
/// `Error` carrying this prefix. Classify with [`is_busy_error`].
pub const BUSY_ERROR_PREFIX: &str = "busy:";

/// True iff `e` is an admission-control busy shed (retryable): the
/// command was **not** executed and was **not** queued — re-issuing it
/// with the same request id is safe (the executors' dedup window
/// absorbs the duplicate if a race ever executes both).
pub fn is_busy_error(e: &Error) -> bool {
    e.to_string().starts_with(BUSY_ERROR_PREFIX)
}

/// A client session: the identity and request-id allocator behind every
/// command a client submits. Sequence numbers start at 1 and never repeat
/// within a session, so `(client, seq)` names a request uniquely for the
/// lifetime of the deployment (assuming client ids are unique, which the
/// runtimes enforce by construction).
#[derive(Clone, Debug)]
pub struct Session {
    client: ClientId,
    next_seq: u64,
    /// Read-your-writes watermark: the highest decided timestamp among
    /// this session's acknowledged writes (0 before the first ack).
    write_watermark: u64,
}

impl Session {
    /// Open a session for `client`.
    pub fn new(client: ClientId) -> Self {
        Session { client, next_seq: 1, write_watermark: 0 }
    }

    /// The session's client identity.
    pub fn client(&self) -> ClientId {
        self.client
    }

    /// Number of request ids allocated so far.
    pub fn issued(&self) -> u64 {
        self.next_seq - 1
    }

    /// A write of this session was acknowledged with decided timestamp
    /// `ts` (`Action::Reply::ts`): raise the read-your-writes watermark.
    /// Timestamp-free protocol families report 0, which leaves the floor
    /// untouched — their ordering path serializes reads after writes
    /// anyway.
    pub fn note_write(&mut self, ts: u64) {
        self.write_watermark = self.write_watermark.max(ts);
    }

    /// The floor to pass to `Protocol::submit_read`: reads of this session
    /// must observe state at least as fresh as its last acknowledged
    /// write.
    pub fn read_floor(&self) -> u64 {
        self.write_watermark
    }

    /// Allocate the next request id.
    pub fn next_rid(&mut self) -> Rid {
        let rid = Rid::new(self.client, self.next_seq);
        self.next_seq += 1;
        rid
    }

    /// Build a command carrying a fresh request id.
    pub fn command(&mut self, keys: Vec<Key>, op: Op, payload_len: u32) -> Command {
        Command::new(self.next_rid(), keys, op, payload_len)
    }

    /// Single-key shorthand for [`Session::command`].
    pub fn single(&mut self, key: Key, op: Op, payload_len: u32) -> Command {
        Command::single(self.next_rid(), key, op, payload_len)
    }

    /// Build a read-only command over `keys` ([`Op::Read`], the
    /// stability-powered read class): submitted via
    /// `Protocol::submit_read`, it is served at the contacted replica with
    /// zero protocol messages once the stability frontier covers its
    /// timestamp (on protocol families without a frontier it degrades to
    /// the ordinary ordering path). On the wire it is a `ClientSubmit`
    /// frame whose command carries op tag 3 (docs/WIRE.md).
    pub fn read(&mut self, keys: Vec<Key>) -> Command {
        Command::read(self.next_rid(), keys)
    }

    /// Single-key shorthand for [`Session::read`].
    pub fn read_single(&mut self, key: Key) -> Command {
        Command::read(self.next_rid(), vec![key])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rids_are_unique_and_monotone() {
        let mut s = Session::new(ClientId(7));
        let a = s.next_rid();
        let b = s.next_rid();
        assert_eq!(a, Rid::new(ClientId(7), 1));
        assert_eq!(b, Rid::new(ClientId(7), 2));
        assert!(a < b);
        assert_eq!(s.issued(), 2);
    }

    #[test]
    fn commands_carry_session_identity() {
        let mut s = Session::new(ClientId(3));
        let c1 = s.single(9, Op::Put, 64);
        let c2 = s.command(vec![1, 2], Op::Get, 0);
        assert_eq!(c1.client(), ClientId(3));
        assert_eq!(c1.rid, Rid::new(ClientId(3), 1));
        assert_eq!(c2.rid, Rid::new(ClientId(3), 2));
        assert_ne!(c1.rid, c2.rid);
    }

    #[test]
    fn read_floor_tracks_the_highest_acked_write() {
        let mut s = Session::new(ClientId(1));
        assert_eq!(s.read_floor(), 0);
        s.note_write(40);
        s.note_write(25); // a late, lower ack must not lower the floor
        assert_eq!(s.read_floor(), 40);
        s.note_write(0); // timestamp-free families are a no-op
        assert_eq!(s.read_floor(), 40);
    }

    #[test]
    fn busy_errors_classify_by_prefix() {
        let rid = Rid::new(ClientId(4), 2);
        let busy = Error::msg(format!("{BUSY_ERROR_PREFIX} node shed rid {rid:?}"));
        assert!(is_busy_error(&busy));
        assert!(!is_busy_error(&Error::msg("connection reset by peer")));
        // A busy mention elsewhere in the message is not a busy shed.
        assert!(!is_busy_error(&Error::msg("peer busy: backoff")));
    }

    #[test]
    fn sessions_of_different_clients_never_collide() {
        let mut a = Session::new(ClientId(1));
        let mut b = Session::new(ClientId(2));
        let ra: Vec<Rid> = (0..10).map(|_| a.next_rid()).collect();
        let rb: Vec<Rid> = (0..10).map(|_| b.next_rid()).collect();
        for x in &ra {
            assert!(!rb.contains(x));
        }
    }
}
