"""L1 correctness: Pallas stability kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and bit patterns; fixed golden vectors are shared
with the Rust integration test (rust/tests/runtime_bridge.rs), which checks
the same inputs through the AOT artifact against the pure-Rust
PromiseStore implementation.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import highest_contiguous, stable_watermark_ref
from compile.kernels.stability import stable_watermark


def np_reference(bits, majority):
    """Independent numpy implementation (oracle for the oracle)."""
    p, r, w = bits.shape
    out = np.zeros(p, dtype=np.int32)
    for i in range(p):
        h = []
        for j in range(r):
            c = 0
            for u in range(w):
                if bits[i, j, u]:
                    c += 1
                else:
                    break
            h.append(c)
        h.sort()
        out[i] = h[r - majority]
    return out


def test_highest_contiguous_simple():
    bits = np.array([[1, 1, 0, 1], [1, 1, 1, 1], [0, 1, 1, 1]], dtype=np.uint8)
    h = np.asarray(highest_contiguous(bits))
    assert list(h) == [2, 4, 0]


def test_paper_figure2_example():
    # r=3, watermarks {A:2, B:3, C:2} -> stable 2 at majority 2.
    bits = np.zeros((1, 3, 4), dtype=np.uint8)
    bits[0, 0, :2] = 1  # A: promises 1..2
    bits[0, 1, :3] = 1  # B: promises 1..3
    bits[0, 2, :2] = 1  # C: promises 1..2
    assert int(stable_watermark_ref(bits, 2)[0]) == 2
    assert int(stable_watermark_ref(bits, 3)[0]) == 2  # unanimity
    assert int(stable_watermark_ref(bits, 1)[0]) == 3  # any single process
    assert int(stable_watermark(bits, 2)[0]) == 2  # Pallas kernel agrees


def test_gap_blocks_stability():
    # A promise hole at slot 0 pins the watermark at 0 for that process.
    bits = np.ones((1, 3, 8), dtype=np.uint8)
    bits[0, 0, 0] = 0
    bits[0, 1, 0] = 0
    assert int(stable_watermark_ref(bits, 2)[0]) == 0
    assert int(stable_watermark(bits, 2)[0]) == 0


@settings(max_examples=60, deadline=None)
@given(
    p=st.integers(1, 8),
    r=st.integers(3, 7),
    w=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_reference_random(p, r, w, seed):
    rng = np.random.default_rng(seed)
    # Mix dense prefixes (realistic) with random noise (adversarial).
    bits = (rng.random((p, r, w)) < 0.8).astype(np.uint8)
    majority = r // 2 + 1
    expect = np_reference(bits, majority)
    got_ref = np.asarray(stable_watermark_ref(bits, majority))
    got_pallas = np.asarray(stable_watermark(bits, majority))
    np.testing.assert_array_equal(got_ref, expect)
    np.testing.assert_array_equal(got_pallas, expect)


@settings(max_examples=20, deadline=None)
@given(
    r=st.integers(3, 7),
    majority=st.integers(1, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_all_majorities(r, majority, seed):
    if majority > r:
        return
    rng = np.random.default_rng(seed)
    bits = (rng.random((4, r, 32)) < 0.9).astype(np.uint8)
    expect = np_reference(bits, majority)
    np.testing.assert_array_equal(np.asarray(stable_watermark(bits, majority)), expect)


def test_golden_vectors_shared_with_rust():
    """Golden inputs mirrored in rust/tests/runtime_bridge.rs — keep in
    sync. Deterministic bit pattern: bit(i,j,u) = ((i*7 + j*13 + u*3) % 5) != 0
    for the first (i+j+1)*4 slots, zero afterwards."""
    p, r, w = 16, 5, 64
    bits = np.zeros((p, r, w), dtype=np.uint8)
    for i in range(p):
        for j in range(r):
            limit = min(w, (i + j + 1) * 4)
            for u in range(limit):
                bits[i, j, u] = 1 if ((i * 7 + j * 13 + u * 3) % 5) != 0 else 0
    expect = np_reference(bits, 3)
    got = np.asarray(stable_watermark(bits, 3))
    np.testing.assert_array_equal(got, expect)
    # First few values pinned so any drift is loud.
    assert list(got[:4]) == list(expect[:4])


def test_executor_tick_masks_queue():
    from compile.model import executor_tick

    bits = np.ones((2, 3, 8), dtype=np.uint8)
    bits[1, :, 4:] = 0  # partition 1 stable only up to 4
    queue = np.array([[1, 8, 0, 9], [4, 5, 1, 0]], dtype=np.int32)
    wm, mask = executor_tick(bits, queue, majority=2)
    assert list(np.asarray(wm)) == [8, 4]
    assert np.asarray(mask).tolist() == [[1, 1, 0, 0], [1, 0, 1, 0]]
