"""AOT-compile the L2 executor-tick graph to HLO text.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out ../artifacts/stability.hlo.txt``
(from the ``python/`` directory). Shapes are static per artifact; the Rust
runtime picks the artifact matching its configuration.
"""

import argparse
import functools

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import executor_tick

# Default artifact shape: 16 partitions x r=5 replicas x 64-slot promise
# window, queue depth 16, majority 3 (r=5 -> floor(r/2)+1).
P, R, W, Q, MAJORITY = 16, 5, 64, 16, 3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(p=P, r=R, w=W, q=Q, majority=MAJORITY):
    fn = functools.partial(executor_tick, majority=majority)
    bits = jax.ShapeDtypeStruct((p, r, w), jnp.uint8)
    queue = jax.ShapeDtypeStruct((p, q), jnp.int32)
    return jax.jit(fn).lower(bits, queue)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/stability.hlo.txt")
    ap.add_argument("--partitions", type=int, default=P)
    ap.add_argument("--replicas", type=int, default=R)
    ap.add_argument("--window", type=int, default=W)
    ap.add_argument("--queue", type=int, default=Q)
    ap.add_argument("--majority", type=int, default=MAJORITY)
    args = ap.parse_args()
    lowered = lower(args.partitions, args.replicas, args.window, args.queue, args.majority)
    text = to_hlo_text(lowered)
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {args.out}")


if __name__ == "__main__":
    main()
