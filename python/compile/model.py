"""L2: the executor-tick compute graph, calling the L1 Pallas kernel.

One executor tick of the Tempo execution protocol (Algorithm 2/6), batched
over partitions:

1. stability — per-partition stable watermark from the promise bitmap
   (the Pallas kernel, ``kernels.stability``);
2. an executability mask over the committed-command queue: a queue entry
   with timestamp ``ts`` executes iff ``0 < ts <= watermark`` of its
   partition.

Python runs only at build time: ``aot.py`` lowers this function once to
HLO text and the Rust coordinator (rust/src/runtime) loads and executes
the artifact on its PJRT CPU client.
"""

import jax.numpy as jnp

from .kernels.stability import stable_watermark


def executor_tick(bits, queue_ts, majority):
    """Batched executor tick.

    ``bits``: uint8 ``[P, r, W]`` promise bitmap.
    ``queue_ts``: int32 ``[P, Q]`` committed-queue timestamps (0 = empty
    slot).
    Returns ``(watermark [P] int32, executable [P, Q] int32)``.
    """
    watermark = stable_watermark(bits, majority)  # [P]
    executable = (queue_ts > 0) & (queue_ts <= watermark[:, None])
    return watermark, executable.astype(jnp.int32)
