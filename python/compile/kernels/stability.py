"""L1 Pallas kernel: batched timestamp-stability detection.

The executor hot-spot of Tempo (paper Algorithm 2 lines 49-51) as a Pallas
kernel: for every partition, compute each replica's highest contiguous
promise and take the majority-th order statistic.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid iterates over
partitions — the role threadblocks would play on a GPU — and each grid step
holds one ``[r, W]`` uint8 tile in VMEM (r*W bytes, ~KBs, far below the
VMEM budget). The contiguous-prefix scan is expressed with ``cumprod``
along the W lanes (VPU-friendly, no MXU needed — this is a bitwise
workload, not a matmul). ``interpret=True`` is mandatory on CPU: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stability_kernel(majority, bits_ref, out_ref):
    """One grid step: bits_ref [1, r, W] uint8 -> out_ref [1] int32."""
    bits = bits_ref[0].astype(jnp.int32)  # [r, W], VMEM-resident tile
    prefix = jnp.cumprod(bits, axis=-1)  # [r, W]
    h = jnp.sum(prefix, axis=-1)  # [r]
    h_sorted = jnp.sort(h)  # ascending
    r = h.shape[0]
    out_ref[0] = h_sorted[r - majority].astype(jnp.int32)


def stable_watermark(bits, majority):
    """Pallas-accelerated stability detection.

    ``bits``: uint8 ``[P, r, W]`` promise bitmap.
    Returns int32 ``[P]``.
    """
    p, r, w = bits.shape

    def kernel(bits_ref, out_ref):
        _stability_kernel(majority, bits_ref, out_ref)

    return pl.pallas_call(
        kernel,
        grid=(p,),
        in_specs=[pl.BlockSpec((1, r, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(bits)
