"""Pure-jnp oracle for the stability kernel (correctness reference).

Stability detection (paper Theorem 1 / Algorithm 2 lines 49-51), batched
over partitions: given a promise bitmap ``bits[P, r, W]`` (``bits[p, j, u]``
= 1 iff process ``j``'s promise for timestamp ``u+1`` on partition ``p`` is
known), the stable watermark of partition ``p`` is the ``majority``-th
largest value among the per-process *highest contiguous promise* counts
(``h[floor(r/2)]`` in the paper's sorted array).
"""

import jax.numpy as jnp


def highest_contiguous(bits):
    """Length of the all-ones prefix along the last axis.

    ``bits``: uint8/bool array ``[..., W]`` -> int32 ``[...]``.
    """
    prefix = jnp.cumprod(bits.astype(jnp.int32), axis=-1)
    return jnp.sum(prefix, axis=-1).astype(jnp.int32)


def stable_watermark_ref(bits, majority):
    """Reference stability computation.

    ``bits``: ``[P, r, W]`` promise bitmap.
    ``majority``: how many processes must have contiguous promises
    (``floor(r/2) + 1`` in the paper).

    Returns int32 ``[P]``: the highest timestamp stable at each partition.
    """
    h = highest_contiguous(bits)  # [P, r]
    h_sorted = jnp.sort(h, axis=-1)  # ascending
    r = bits.shape[-2]
    # `majority` processes have watermark >= h_sorted[r - majority].
    return h_sorted[..., r - majority]
