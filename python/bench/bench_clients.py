"""Event-loop client plane, measured via a Python port.

Faithful port of the mechanics the event-loop client plane adds
(rust/src/net/mod.rs: ``client_loop`` + ``FrameDecoder`` +
per-connection reply queues + admission control), measured for real on
this machine (no Rust toolchain in this container; ``cargo run
--release --example e2e_cluster -- --sweep-clients`` records the
real-TCP companion file BENCH_clients_tcp.json):

1. **Session sweep** — 1k / 10k / 100k client sessions multiplexed over
   a fixed pool of event loops (no per-session thread, ever). Every
   submit travels as real encoded bytes: transport-framed
   ``ClientSubmit`` through the incremental ``FrameDecoder`` on the node
   side, replies batched per connection and flushed as ONE concatenated
   ("vectored") write per wakeup, decoded back through the client's own
   ``FrameDecoder``. Reported per cell: ops/s, p99 latency, wire
   bytes/op, and replies-per-flush (> 1 ⇔ the loop batches replies).
   The point the gate holds us to: per-op cost must stay flat as the
   session table grows 10x — the loop's cost is per *event*, not per
   *connection*.

2. **Admission control** — a burst cell drives one session far past
   ``max_inflight_per_session``; the node sheds the excess at the edge
   with explicit ``ClientBusy`` frames (tag 25) and the client retries
   only the shed rids until everything completes. Busy sheds observed,
   nothing lost, nothing executed twice.

Run from anywhere: ``python3 python/bench/bench_clients.py``.
``--smoke`` (or ``SMOKE=1``) runs reduced sizes and leaves the recorded
BENCH_clients.json untouched (for cargo-less CI).
"""

import json
import os
import struct
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import wire  # noqa: E402

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"
CLIENT_FROM = (1 << 32) - 1
EVENT_LOOPS = 2
WINDOW = 16  # max_inflight_per_session in the sweep cells
BURST = 4  # submits per session per wakeup -> replies batched per flush


def frame(body):
    """Transport framing: [len u32][from u32][body]."""
    return struct.pack("<I", len(body)) + struct.pack("<I", CLIENT_FROM) + body


class Conn:
    """Node-side state of one multiplexed session: incremental decoder,
    in-flight window, and the outbound reply queue the loop flushes as
    one vectored write per wakeup."""

    __slots__ = ("dec", "inflight", "out")

    def __init__(self):
        self.dec = wire.FrameDecoder()
        self.inflight = 0
        self.out = []


class Loop:
    """One event loop: a token table of connections (the fixed-pool
    multiplexing — adding sessions grows this dict, never the thread
    count) plus flush accounting."""

    __slots__ = ("conns", "flushes", "replies", "bytes")

    def __init__(self):
        self.conns = {}
        self.flushes = 0
        self.replies = 0
        self.bytes = 0


def node_service(loop, token, data, max_inflight, busy_out):
    """Feed one socket read through the connection's decoder; forward
    in-window submits, shed the rest with ClientBusy. Returns the rids
    forwarded to the worker."""
    conn = loop.conns[token]
    forwarded = []
    rest = data
    while rest:
        used, done = conn.dec.feed(rest)
        rest = rest[used:]
        if done:
            assert conn.dec.sender == CLIENT_FROM
            f = wire.decode_client(conn.dec.body)
            conn.dec.clear()
            rid = f["cmd"]["rid"]
            if conn.inflight >= max_inflight:
                busy_out[0] += 1
                conn.out.append(frame(wire.encode_client({"t": "ClientBusy", "rid": rid})))
            else:
                conn.inflight += 1
                forwarded.append(rid)
    return forwarded


def node_flush(loop, token):
    """One vectored write: every queued reply frame of this connection
    leaves in a single flush."""
    conn = loop.conns[token]
    if not conn.out:
        return b""
    buf = b"".join(conn.out)
    loop.flushes += 1
    loop.replies += len(conn.out)
    loop.bytes += len(buf)
    conn.out.clear()
    return buf


def sweep_cell(sessions, total_ops):
    """Drive `total_ops` submits round-robin over `sessions` sessions
    multiplexed on EVENT_LOOPS loops; measure ops/s, p99, replies/flush."""
    loops = [Loop() for _ in range(EVENT_LOOPS)]
    client_dec = [wire.FrameDecoder() for _ in range(sessions)]
    for s in range(sessions):
        loops[s % EVENT_LOOPS].conns[s] = Conn()
    ops_per_session = max(1, total_ops // sessions)
    busy = [0]
    latencies = []
    completed = 0
    start = time.perf_counter()
    remaining = [ops_per_session] * sessions
    seq = [0] * sessions
    rounds = (ops_per_session + BURST - 1) // BURST
    for _ in range(rounds):
        for s in range(sessions):
            if remaining[s] == 0:
                continue
            loop = loops[s % EVENT_LOOPS]
            burst = min(BURST, remaining[s])
            remaining[s] -= burst
            t0 = time.perf_counter()
            # Client: one socket write carrying `burst` submit frames.
            parts = []
            for _ in range(burst):
                seq[s] += 1
                cmd = {
                    "rid": (1_000_000 + s, seq[s]),
                    "op": 1,
                    "payload_len": 64,
                    "batched": 0,
                    "keys": [s * 31 + seq[s]],
                }
                parts.append(frame(wire.encode_client({"t": "ClientSubmit", "cmd": cmd, "floor": 0})))
            # Node: incremental decode, window check, forward.
            fwd = node_service(loop, s, b"".join(parts), WINDOW, busy)
            # Worker: complete everything forwarded; replies queue on the
            # connection and leave in ONE flush (the batched vectored write).
            conn = loop.conns[s]
            for rid in fwd:
                conn.inflight -= 1
                reply = {"t": "ClientReply", "rid": rid, "response": [(rid[1], 1)], "ts": seq[s]}
                conn.out.append(frame(wire.encode_client(reply)))
            flushed = node_flush(loop, s)
            # Client: decode the reply batch through its own decoder.
            dec, rest = client_dec[s], flushed
            while rest:
                used, done = dec.feed(rest)
                rest = rest[used:]
                if done:
                    assert dec.sender == CLIENT_FROM
                    assert wire.decode_client(dec.body)["t"] == "ClientReply"
                    dec.clear()
                    completed += 1
                    latencies.append(time.perf_counter() - t0)
    el = time.perf_counter() - start
    assert busy[0] == 0, "sweep cells stay inside the window"
    flushes = sum(lo.flushes for lo in loops)
    replies = sum(lo.replies for lo in loops)
    latencies.sort()
    return {
        "sessions": sessions,
        "event_loops": EVENT_LOOPS,
        "window": WINDOW,
        "ops": completed,
        "ops_per_s": round(completed / el),
        "p99_us": round(latencies[int(len(latencies) * 0.99) - 1] * 1e6, 1),
        "wire_bytes_per_op": round(sum(lo.bytes for lo in loops) / completed, 1),
        "replies_per_flush": round(replies / flushes, 2),
    }


def busy_cell():
    """One session bursts far past the window: the node sheds with
    explicit ClientBusy frames, the client retries only the shed rids,
    and everything eventually completes exactly once."""
    window, burst = 4, 64
    loop = Loop()
    loop.conns[0] = Conn()
    client = wire.FrameDecoder()
    busy = [0]
    pending = [(1, i) for i in range(1, burst + 1)]
    completed = set()
    busy_errors = 0
    rounds = 0
    while pending and rounds < 1000:
        rounds += 1
        parts = []
        for rid in pending:
            cmd = {"rid": rid, "op": 1, "payload_len": 32, "batched": 0, "keys": [rid[1]]}
            parts.append(frame(wire.encode_client({"t": "ClientSubmit", "cmd": cmd, "floor": 0})))
        fwd = node_service(loop, 0, b"".join(parts), window, busy)
        conn = loop.conns[0]
        for rid in fwd:
            conn.inflight -= 1
            conn.out.append(
                frame(wire.encode_client({"t": "ClientReply", "rid": rid, "response": [], "ts": 1}))
            )
        rest = node_flush(loop, 0)
        shed = []
        while rest:
            used, done = client.feed(rest)
            rest = rest[used:]
            if done:
                f = wire.decode_client(client.body)
                client.clear()
                if f["t"] == "ClientBusy":
                    busy_errors += 1
                    shed.append(f["rid"])  # retry exactly the shed rid
                else:
                    assert f["rid"] not in completed, "duplicate completion"
                    completed.add(f["rid"])
        pending = shed
    assert not pending, "busy retries never converged"
    assert len(completed) == burst, f"{len(completed)}/{burst} completed"
    assert busy[0] > 0 and busy_errors == busy[0]
    return {
        "window": window,
        "burst": burst,
        "completed": len(completed),
        "busy_shed": busy[0],
        "retry_rounds": rounds,
    }


def main():
    sweep = [1_000, 10_000] if SMOKE else [1_000, 10_000, 100_000]
    total_ops = 20_000 if SMOKE else 200_000
    cells = []
    for sessions in sweep:
        c = sweep_cell(sessions, total_ops)
        print(
            f"sessions={sessions:>6}: {c['ops_per_s']:>8} ops/s, "
            f"p99 {c['p99_us']:>7} us, {c['replies_per_flush']} replies/flush, "
            f"{c['wire_bytes_per_op']} B/op on {EVENT_LOOPS} loops"
        )
        cells.append(c)
    by_sessions = {c["sessions"]: c for c in cells}
    ratio = by_sessions[10_000]["ops_per_s"] / by_sessions[1_000]["ops_per_s"]
    print(f"10k vs 1k sessions ops/s ratio: {ratio:.2f} (flat-cost target >= 0.8)")
    busy = busy_cell()
    print(
        f"admission control: burst {busy['burst']} into window {busy['window']} "
        f"-> {busy['busy_shed']} busy sheds, {busy['completed']} completed over "
        f"{busy['retry_rounds']} retry rounds"
    )
    result = {
        "bench": "event_loop_clients",
        "harness": "python port (python/bench/bench_clients.py); no Rust "
        "toolchain in this container — numbers are Python-speed but the "
        "mechanics are real: every submit/reply is encoded, transport-"
        "framed, fed through the incremental FrameDecoder and flushed as "
        "one vectored write per wakeup. The real-TCP companion is "
        "BENCH_clients_tcp.json (examples/e2e_cluster.rs --sweep-clients)",
        "workload": f"{total_ops} single-key Put ops round-robin over the "
        f"session table, burst {BURST} per session per wakeup, "
        f"{EVENT_LOOPS} event loops, window {WINDOW}",
        "cells": cells,
        "ratio_10k_vs_1k_ops": round(ratio, 3),
        "busy": busy,
        "regenerate": "python3 python/bench/bench_clients.py (real TCP: "
        "ulimit -n 65536 && cargo run --release --example e2e_cluster -- "
        "--sweep-clients)",
    }
    if SMOKE:
        print(json.dumps(result, indent=2))
        print("smoke mode: BENCH_clients.json left untouched")
        return
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(os.path.join(root, "BENCH_clients.json"))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"written to {path}")


if __name__ == "__main__":
    main()
