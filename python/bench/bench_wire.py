"""Encode-once fan-out, measured via the Python codec port.

Faithful port of ``rust/benches/wire.rs`` (no Rust toolchain in this
container; ``cargo bench --bench wire`` overwrites BENCH_wire.json with
the Rust measurements). Per (message shape, fan-out) cell:

- **legacy**: encode the routed frame once *per destination* — the
  pre-PR-5 send path, where ns/op and buffers/op scale with fan-out.
- **encode-once**: encode a single body and hand every destination a
  reference to it (the Rust runtime's ``Arc<[u8]>``/``SendBytes`` path)
  — ns/op and allocations/op must stay flat (± O(1)) as fan-out grows
  1 → 8. That flatness is what ``check_bench.py`` gates.

Allocation accounting: Python cannot count cumulative heap allocations
without C hooks, so ``allocs_per_op`` is the *net retained blocks per
op* while a window of in-flight fan-outs is held live
(``sys.getallocatedblocks`` delta) — exactly the number of frame
buffers a window of sends pins. Legacy retains ``fanout`` buffers per
op; encode-once retains ~1 regardless of fan-out. The Rust bench's
counting allocator measures true allocations/op and overwrites this
file.

The message shapes cover the fan-outs the protocol families send: a
command-bearing proposal (Tempo ``MPropose`` ≈ EPaxos ``PreAccept`` ≈
Caesar ``Propose``), a commit carrying collected promise/dependency
payloads (Tempo ``MCommit`` ≈ Caesar commit+deps), and the periodic
promise delta (``MPromises``). All encode through the Tempo codec — the
one wire codec the runtime ships.

Run from anywhere: ``python3 python/bench/bench_wire.py``. ``--smoke``
(or ``SMOKE=1``) runs reduced iterations and leaves the recorded
BENCH_wire.json untouched (for cargo-less CI).
"""

import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from wire import encode_routed  # noqa: E402

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"
ITERS = 2_000 if SMOKE else 20_000
WINDOW = 64 if SMOKE else 256
FANOUTS = (1, 4, 8)

DOT = (0, 7)
CMD = {"rid": (3, 11), "op": 2, "payload_len": 100, "batched": 1, "keys": [42, 99]}


def promise_set(n):
    return ([(10 * i + 1, 10 * i + 5) for i in range(n)], [(DOT, 10 * n + 1)])


KP = [(42, promise_set(4)), (99, promise_set(4))]

MESSAGES = [
    (
        "propose_cmd100B",
        {
            "t": "MPropose",
            "dot": DOT,
            "cmd": CMD,
            "quorums": [(0, [0, 1, 2])],
            "ts": [(42, 17), (99, 18)],
        },
    ),
    (
        "commit_promises",
        {
            "t": "MCommit",
            "dot": DOT,
            "group": 0,
            "ts": [(42, 17), (99, 18)],
            "promises": [(1, KP), (2, KP)],
        },
    ),
    ("promise_delta", {"t": "MPromises", "promises": KP}),
]


def measure(msg, fanout):
    # --- ns/op ---
    t0 = time.perf_counter()
    for _ in range(ITERS):
        for _ in range(fanout):
            encode_routed(0, msg)  # legacy: one encode per destination
    legacy_ns = (time.perf_counter() - t0) / ITERS * 1e9

    t0 = time.perf_counter()
    sink = []
    for _ in range(ITERS):
        body = encode_routed(0, msg)  # encode-once: one body ...
        handles = [body] * fanout  # ... shared by every destination
        sink.append(len(handles))
    once_ns = (time.perf_counter() - t0) / ITERS * 1e9
    del sink

    # --- retained buffers per op (allocation proxy, see module doc) ---
    blocks0 = sys.getallocatedblocks()
    window = [[encode_routed(0, msg) for _ in range(fanout)] for _ in range(WINDOW)]
    legacy_allocs = max(0, sys.getallocatedblocks() - blocks0) / WINDOW
    del window

    blocks0 = sys.getallocatedblocks()
    window = []
    for _ in range(WINDOW):
        body = encode_routed(0, msg)
        window.append([body] * fanout)
    once_allocs = max(0, sys.getallocatedblocks() - blocks0) / WINDOW
    del window

    return {
        "fanout": fanout,
        "legacy_ns_per_op": round(legacy_ns, 1),
        "legacy_allocs_per_op": round(legacy_allocs, 2),
        "encode_once_ns_per_op": round(once_ns, 1),
        "encode_once_allocs_per_op": round(once_allocs, 2),
    }


def main():
    messages = []
    for name, msg in MESSAGES:
        bytes_per_encode = len(encode_routed(0, msg))
        cells = []
        print(f"{name} ({bytes_per_encode} B routed):")
        for fanout in FANOUTS:
            c = measure(msg, fanout)
            print(
                f"  fanout {fanout}: legacy {c['legacy_ns_per_op']:>9.1f} ns/op "
                f"{c['legacy_allocs_per_op']:>6.2f} bufs/op | encode-once "
                f"{c['encode_once_ns_per_op']:>9.1f} ns/op "
                f"{c['encode_once_allocs_per_op']:>6.2f} bufs/op"
            )
            cells.append(c)
        messages.append(
            {"msg": name, "bytes_per_encode": bytes_per_encode, "fanout_cells": cells}
        )

    result = {
        "bench": "wire_encode_once",
        "workload": "representative command/commit/promise fan-out shapes, "
        "routed-frame encode, fan-out 1/4/8",
        "note": "legacy = one encode per destination (the pre-PR-5 send path); "
        "encode_once = one shared body. The gate: encode_once allocs/op and "
        "ns/op stay flat (+-O(1)) as fan-out grows 1->8",
        "harness": "python port (python/bench/bench_wire.py); no Rust toolchain "
        "in this container — numbers are Python-speed but measured for real: "
        "perf_counter ns/op and sys.getallocatedblocks retained buffers per "
        "op. `cargo bench --bench wire` overwrites this file with Rust "
        "counting-allocator numbers",
        "allocs_per_op_semantics": "net retained blocks/op while a window of "
        "fan-outs is in flight (python port); true allocations/op under the "
        "Rust harness",
        "messages": messages,
        "regenerate": "cargo bench --bench wire",
    }
    if SMOKE:
        print(json.dumps(result, indent=2))
        print("smoke mode: BENCH_wire.json left untouched")
        return
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(os.path.join(root, "BENCH_wire.json"))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"written to {path}")


if __name__ == "__main__":
    main()
