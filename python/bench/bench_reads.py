"""Stability-powered local reads, measured via a Python port.

Faithful port of what PR 6 adds (no Rust toolchain in this container;
``cargo bench --bench reads`` overwrites BENCH_reads.json with the Rust
simulator numbers): a read-flagged command at the coordinator skips the
ordering path entirely when the stability frontier already covers its
timestamp — no proposal, no quorum round-trip, no wire bytes.

Three measurements, mirroring rust/benches/reads.rs:

1. **Local-read service rate**: a hot loop of the coordinator read path —
   per-key state lookup, frontier-coverage check (``watermark >= target``,
   the O(1) cached majority watermark from PR 1), KV apply, reply tuple —
   reported as reads/s with wire bytes *counted*, not assumed (the gate
   wants ~zero bytes per local read) and net retained blocks per read.

2. **Write-path baseline**: ops/s of the ordering path a read skips,
   ported end-to-end per command: clock bump, MPropose encoded to the
   fast quorum through the real ``wire.py`` codec, peer decode + clock
   merge + MProposeAck encode, coordinator ack decode, highest-ts commit,
   MCommit encode/decode to all peers, promise-frontier update, majority
   watermark, execution-queue advance, KV apply. The headline ratio
   (local-read rate / write-path rate) is what coordination-free buys.

3. **Mix cells**: 95/5 and 50/50 read/write mixes at zipf θ 0.5 / 0.99 —
   every read must serve locally (``local_reads`` counts them; a read
   whose target is not yet covered parks and is served when the next
   write advances the frontier, still locally).

Run from anywhere: ``python3 python/bench/bench_reads.py``.
``--smoke`` (or ``SMOKE=1``) runs reduced iterations and leaves the
recorded BENCH_reads.json untouched (for cargo-less CI).
"""

import bisect
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import wire  # noqa: E402

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"
R, MAJORITY = 3, 2  # r=3 f=1, the paper's planet-scale sweet spot
N_KEYS = 10_000
OPS = 20_000 if SMOKE else 120_000
MICRO_N = 100_000 if SMOKE else 1_000_000
PAYLOAD = 100


def zipf_keys(theta, n_ops, seed):
    """Pre-drawn zipf(theta) key stream over N_KEYS keys."""
    rng = random.Random(seed)
    if theta == 0.0:
        return [rng.randrange(N_KEYS) for _ in range(n_ops)]
    weights = [1.0 / ((i + 1) ** theta) for i in range(N_KEYS)]
    total = sum(weights)
    cdf, acc = [], 0.0
    for w in weights:
        acc += w / total
        cdf.append(acc)
    return [bisect.bisect_left(cdf, rng.random()) for _ in range(n_ops)]


class KeyState:
    """Per-key protocol state: clock, per-source promise frontiers with
    the cached majority watermark, and the ts-ordered execution queue —
    the same shape bench_workers.py ports from tempo/mod.rs."""

    __slots__ = ("clock", "frontiers", "watermark", "queue")

    def __init__(self):
        self.clock = 0
        self.frontiers = [0] * R
        self.watermark = 0
        self.queue = []


class Replica:
    __slots__ = ("states", "kv")

    def __init__(self):
        self.states = {}
        self.kv = {}

    def state(self, key):
        s = self.states.get(key)
        if s is None:
            s = self.states[key] = KeyState()
        return s


def write_path_op(replicas, key, seq, wire_bytes):
    """One command through the full ordering path, frames included.

    Returns outbound wire bytes charged at the coordinator (the quantity
    a local read never pays)."""
    coord = replicas[0]
    state = coord.state(key)
    state.clock += 1
    ts = state.clock
    cmd = {
        "rid": (1, seq),
        "op": 1,  # Put
        "payload_len": PAYLOAD,
        "batched": 0,
        "keys": [key],
    }
    dot = (0, seq)
    propose = wire.encode(
        {"t": "MPropose", "dot": dot, "cmd": cmd,
         "quorums": [(0, list(range(R)))], "ts": [(key, ts)]}
    )
    # Fast quorum: coordinator + (MAJORITY - 1) peers.
    acks = []
    for p in range(1, MAJORITY):
        wire_bytes[0] += len(propose)
        msg = wire.decode(propose)
        peer = replicas[p]
        pstate = peer.state(msg["cmd"]["keys"][0])
        proposed = msg["ts"][0][1]
        if proposed > pstate.clock:
            pstate.clock = proposed
        pts = pstate.clock
        ack = wire.encode(
            {"t": "MProposeAck", "dot": msg["dot"], "ts": [(key, pts)],
             "promises": [(key, ([(pts, pts)], []))]}
        )
        acks.append(ack)
    final_ts = ts
    for ack in acks:
        wire_bytes[0] += len(ack)
        msg = wire.decode(ack)
        final_ts = max(final_ts, msg["ts"][0][1])
    commit = wire.encode(
        {"t": "MCommit", "dot": dot, "group": 0, "ts": [(key, final_ts)],
         "promises": [(0, [(key, ([(final_ts, final_ts)], []))])]}
    )
    for p in range(1, R):
        wire_bytes[0] += len(commit)
        wire.decode(commit)
    # Commit at the coordinator: promise frontiers from the quorum, the
    # majority watermark, queue advance, KV apply.
    if final_ts > state.clock:
        state.clock = final_ts
    for src in range(MAJORITY):
        if final_ts > state.frontiers[src]:
            state.frontiers[src] = final_ts
    w = sorted(state.frontiers)[R - MAJORITY]
    if w > state.watermark:
        state.watermark = w
    bisect.insort(state.queue, final_ts)
    while state.queue and state.queue[0] <= state.watermark:
        state.queue.pop(0)
    coord.kv[key] = seq
    return final_ts


def local_read(coord, key):
    """The PR 6 coordinator read path: O(1) coverage check, no frames.

    Returns (value, served_instantly)."""
    state = coord.states.get(key)
    if state is None:
        return None, True  # nothing ordered for this key: frontier covers 0
    target = state.clock
    if state.watermark >= target and not state.queue:
        return coord.kv.get(key), True
    return None, False  # parks; the next write's frontier advance serves it


def micro_local_reads(n):
    """Hot loop of n instant local reads against one warmed replica.
    Returns (reads/s, wire bytes/read, net retained blocks/read)."""
    coord = Replica()
    wire_bytes = [0]
    for k in range(1024):
        write_path_op([coord, Replica(), Replica()], k, k + 1, wire_bytes)
    wire_bytes[0] = 0  # warmup framing is not the read path's bill
    served = 0
    blocks0 = sys.getallocatedblocks()
    start = time.perf_counter()
    for i in range(n):
        value, instant = local_read(coord, i % 1024)
        if instant:
            served += 1
            _reply = (value,)
    el = time.perf_counter() - start
    retained = max(0, sys.getallocatedblocks() - blocks0)
    assert served == n, f"every read must serve locally ({served}/{n})"
    assert wire_bytes[0] == 0, "a local read must send nothing"
    return n / el, wire_bytes[0] / n, retained / n


def mix(read_ratio, theta, seed):
    """A read/write mix through the ported paths; every read must serve
    locally (instantly, or parked until the next write covers it)."""
    keys = zipf_keys(theta, OPS, seed)
    rng = random.Random(seed + 1)
    is_read = [rng.random() < read_ratio for _ in range(OPS)]
    replicas = [Replica() for _ in range(R)]
    coord = replicas[0]
    wire_bytes = [0]
    local_reads = slow_reads = parked = 0
    stash = {}  # key -> parked read count
    start = time.perf_counter()
    for i, k in enumerate(keys):
        if is_read[i]:
            _value, instant = local_read(coord, k)
            if instant:
                local_reads += 1
            else:
                parked += 1
                stash[k] = stash.get(k, 0) + 1
        else:
            write_path_op(replicas, k, i + 1, wire_bytes)
            waiting = stash.pop(k, 0)
            if waiting:
                # The frontier now covers the key's clock: serve them.
                for _ in range(waiting):
                    _value, instant = local_read(coord, k)
                    assert instant, "post-commit frontier must cover the key"
                    local_reads += 1
    # Drain: a quiet key's parked reads are served by one covering write.
    for k, waiting in list(stash.items()):
        write_path_op(replicas, k, OPS + k + 1, wire_bytes)
        for _ in range(waiting):
            _value, instant = local_read(coord, k)
            assert instant
            local_reads += 1
    el = time.perf_counter() - start
    return {
        "read_pct": int(read_ratio * 100),
        "zipf_theta": theta,
        "contention": "low" if theta < 0.9 else "high",
        "ops": OPS,
        "ops_per_s_wall": round(OPS / el),
        "local_reads": local_reads,
        "slow_reads": slow_reads,
        "parked_then_served": parked,
        "write_wire_bytes": wire_bytes[0],
    }


def main():
    reads_per_s, bytes_per_read, blocks_per_read = micro_local_reads(MICRO_N)
    print(
        f"local reads : {reads_per_s:>12.0f} reads/s, "
        f"{bytes_per_read:.4f} wire B/read, "
        f"{blocks_per_read:.3f} retained blocks/read"
    )

    baseline = mix(0.0, 0.5, seed=7)
    write_ops_per_s = baseline["ops_per_s_wall"]
    print(
        f"write path  : {write_ops_per_s:>12.0f} ops/s "
        f"({baseline['write_wire_bytes']} wire bytes over {OPS} ops)"
    )
    speedup = reads_per_s / write_ops_per_s
    print(f"read speedup vs write path: {speedup:.1f}x")

    cells = []
    for ratio, theta in ((0.95, 0.5), (0.95, 0.99), (0.5, 0.5), (0.5, 0.99)):
        c = mix(ratio, theta, seed=11)
        print(
            f"mix {c['read_pct']}/{100 - c['read_pct']} theta={theta:<4}: "
            f"{c['ops_per_s_wall']:>9} ops/s, {c['local_reads']} local reads "
            f"({c['parked_then_served']} parked first), {c['slow_reads']} slow"
        )
        cells.append(c)

    result = {
        "bench": "local_reads",
        "harness": "python port (python/bench/bench_reads.py); no Rust "
        "toolchain in this container — numbers are Python-speed but "
        "measured for real: the coordinator read path (per-key lookup + "
        "O(1) watermark coverage check + KV apply) vs the full ordering "
        "path with MPropose/MProposeAck/MCommit framed through the "
        "wire.py codec. `cargo bench --bench reads` overwrites this file "
        "with the Rust simulator numbers",
        "workload": f"single-key zipf over {N_KEYS} keys, {OPS} ops per "
        f"mix cell, {MICRO_N} micro local reads, r={R} "
        f"majority={MAJORITY}, {PAYLOAD}B write payloads",
        "local_read_ops_per_s": round(reads_per_s),
        "wire_bytes_per_local_read": round(bytes_per_read, 4),
        "allocs_per_local_read": round(blocks_per_read, 3),
        "allocs_semantics": "net retained blocks/read (python port); the "
        "Rust counting allocator records true allocations/read",
        "write_path_ops_per_s": write_ops_per_s,
        "read_speedup_vs_write_path": round(speedup, 1),
        "cells": cells,
        "regenerate": "cargo bench --bench reads",
    }
    if SMOKE:
        print(json.dumps(result, indent=2))
        print("smoke mode: BENCH_reads.json left untouched")
        return
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(os.path.join(root, "BENCH_reads.json"))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"written to {path}")


if __name__ == "__main__":
    main()
