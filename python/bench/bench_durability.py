"""Durability bench: real file-I/O port of the WAL + snapshot subsystem
(rust/src/store/storage/, ``StorageMode::Disk``). Writes
``BENCH_durability.json`` at the repo root (skipped under ``--smoke``).

Where the Rust bench (``cargo bench --bench durability``) measures the
subsystem through the deterministic simulator against a *modelled* disk,
this port journals to actual files in a temp directory — real
``write(2)``/``fsync(2)`` syscalls — so the recorded numbers carry this
machine's storage cost:

- **WAL record framing** is byte-for-byte the Rust layout
  (``wal.rs``): ``[body_len u32][crc32 u32][body]``, CRC-32 (IEEE) over
  the body; payload bytes are never materialized, which is what keeps
  write amplification under the CI gate's 3x budget.
- **Snapshots** mirror ``snapshot.rs``: the store serializes into sorted
  pages of 64 entries (``count u16`` then ``key u64, version u64,
  last_payload u32`` each), pages are content-addressed by FNV-1a-64 and
  written only when absent (a re-checkpoint of unchanged state costs
  zero page writes), then the WAL truncates.
- **Recovery** replays manifest + chunk files + the valid WAL prefix; a
  torn or CRC-corrupt tail ends replay (the group-commit legality
  contract), and the rebuilt store must match the pre-crash store
  exactly.

Cells: in-memory baseline vs disk at fsync batch 1/8/64 (ops/s, write
amplification), then recovery time vs WAL-tail length, with and without
a snapshot shortening the tail.

Usage: python3 bench_durability.py [--smoke]
"""

import bisect
import json
import os
import random
import shutil
import struct
import sys
import tempfile
import time
import zlib

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"
N_KEYS = 10_000
OPS = 8_000 if SMOKE else 120_000
PAYLOAD = 256
SNAPSHOT_EVERY = 1024
CHUNK_KEYS = 64  # rust/src/store/mod.rs CHUNK_KEYS


def zipf_keys(theta, n_ops, seed):
    """Pre-drawn zipf(theta) key stream over N_KEYS keys."""
    rng = random.Random(seed)
    if theta == 0.0:
        return [rng.randrange(N_KEYS) for _ in range(n_ops)]
    weights = [1.0 / ((i + 1) ** theta) for i in range(N_KEYS)]
    total = sum(weights)
    cdf, acc = [], 0.0
    for w in weights:
        acc += w / total
        cdf.append(acc)
    return [bisect.bisect_left(cdf, rng.random()) for _ in range(n_ops)]


# --- WAL record framing (byte-for-byte rust/src/store/storage/wal.rs) ---

def encode_record(index, dot, ts, rid, op, payload_len, batched, keys):
    body = struct.pack("<QIQQQQBIIH", index, dot[0], dot[1], ts, rid[0],
                       rid[1], op, payload_len, batched, len(keys))
    body += b"".join(struct.pack("<Q", k) for k in keys)
    return struct.pack("<II", len(body), zlib.crc32(body)) + body


def decode_records(buf):
    """Longest valid record prefix, mirroring ``wal.rs decode_records``:
    returns (records, bytes consumed); a torn length/body or a CRC
    mismatch ends replay."""
    records, at = [], 0
    while at + 8 <= len(buf):
        length, crc = struct.unpack_from("<II", buf, at)
        body = buf[at + 8 : at + 8 + length]
        if len(body) < length or zlib.crc32(body) != crc:
            break
        index, origin, dotseq, ts, client, ridseq, op, payload_len, batched, nkeys = (
            struct.unpack_from("<QIQQQQBIIH", body)
        )
        base = struct.calcsize("<QIQQQQBIIH")
        if op > 3 or base + 8 * nkeys != length:
            break
        keys = list(struct.unpack_from(f"<{nkeys}Q", body, base)) if nkeys else []
        records.append({
            "index": index, "dot": (origin, dotseq), "ts": ts,
            "rid": (client, ridseq), "op": op, "payload_len": payload_len,
            "batched": batched, "keys": keys,
        })
        at += 8 + length
    return records, at


# --- Store + snapshot chunking (rust/src/store/mod.rs, snapshot.rs) ---

def fnv1a64(data):
    h = 0xCBF29CE484222325
    for b in data:
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


class Store:
    """dict-of-(version, last_payload), the KvStore shape that matters to
    durability: Put bumps the version and records the payload length."""

    def __init__(self):
        self.data = {}
        self.applied = 0

    def put(self, key, payload_len):
        version, _ = self.data.get(key, (0, 0))
        self.data[key] = (version + 1, payload_len)
        self.applied += 1

    def to_chunks(self):
        entries = sorted(self.data.items())
        pages = []
        for at in range(0, len(entries), CHUNK_KEYS):
            page = entries[at : at + CHUNK_KEYS]
            buf = struct.pack("<H", len(page))
            for k, (version, last_payload) in page:
                buf += struct.pack("<QQI", k, version, last_payload)
            pages.append(buf)
        return pages

    def digest(self):
        return fnv1a64(b"".join(self.to_chunks()) + struct.pack("<Q", self.applied))


class DiskBackend:
    """Real files: one WAL (append + fsync), content-addressed chunk
    files, and a manifest — the FileBackend layout, one slot."""

    def __init__(self, root):
        self.root = root
        os.makedirs(os.path.join(root, "chunks"), exist_ok=True)
        self.wal_path = os.path.join(root, "wal.log")
        self.wal = open(self.wal_path, "ab")
        self.bytes_written = 0
        self.fsyncs = 0
        self.chunks_written = 0
        self.chunks_reused = 0

    def append_wal(self, rec):
        self.wal.write(rec)
        self.bytes_written += len(rec)

    def sync_wal(self):
        self.wal.flush()
        os.fsync(self.wal.fileno())
        self.fsyncs += 1

    def put_chunk(self, h, page):
        path = os.path.join(self.root, "chunks", f"{h:016x}")
        if os.path.exists(path):
            self.chunks_reused += 1
            return
        with open(path, "wb") as f:
            f.write(page)
        self.chunks_written += 1
        self.bytes_written += len(page)

    def put_manifest(self, manifest):
        blob = json.dumps(manifest).encode()
        path = os.path.join(self.root, "manifest.json")
        with open(path + ".tmp", "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(path + ".tmp", path)  # atomic cutover, like FileBackend
        self.bytes_written += len(blob)
        self.fsyncs += 1

    def truncate_wal(self):
        self.wal.close()
        self.wal = open(self.wal_path, "wb")
        self.wal.close()
        self.wal = open(self.wal_path, "ab")

    def read_manifest(self):
        path = os.path.join(self.root, "manifest.json")
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return json.loads(f.read())

    def get_chunk(self, h):
        path = os.path.join(self.root, "chunks", f"{h:016x}")
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return f.read()

    def read_wal(self):
        with open(self.wal_path, "rb") as f:
            return f.read()

    def close(self):
        self.wal.close()


def checkpoint(store, backend):
    pages = store.to_chunks()
    hashes = [fnv1a64(p) for p in pages]
    for h, p in zip(hashes, pages):
        backend.put_chunk(h, p)
    backend.put_manifest({"applied": store.applied, "chunks": hashes})
    backend.truncate_wal()


def run_cell(mode, fsync_batch, keys, root):
    """One write-path cell: apply OPS single-key Puts, journaling +
    checkpointing when on disk. Returns the stats dict."""
    store = Store()
    backend = DiskBackend(root) if mode == "disk" else None
    pending = 0
    snapshots = 0
    since_snapshot = 0
    t0 = time.perf_counter()
    for i, key in enumerate(keys):
        store.put(key, PAYLOAD)
        if backend is None:
            continue
        backend.append_wal(encode_record(
            store.applied, (0, i + 1), i + 1, (i % 64, i // 64 + 1), 1,
            PAYLOAD, 1, [key]))
        pending += 1
        if pending >= fsync_batch:
            backend.sync_wal()
            pending = 0
        since_snapshot += 1
        if since_snapshot >= SNAPSHOT_EVERY:
            checkpoint(store, backend)
            snapshots += 1
            since_snapshot = 0
    if backend is not None and pending:
        backend.sync_wal()
    wall = time.perf_counter() - t0
    logical = len(keys) * PAYLOAD
    physical = backend.bytes_written if backend else 0
    cell = {
        "mode": mode,
        "fsync_batch": fsync_batch,
        "ops": len(keys),
        "ops_per_s_wall": round(len(keys) / wall),
        "wal_records": len(keys) if backend else 0,
        "fsyncs": backend.fsyncs if backend else 0,
        "snapshots": snapshots,
        "physical_bytes": physical,
        "logical_bytes": logical,
        "write_amp": round(physical / logical, 3) if backend else 0.0,
    }
    if backend:
        backend.close()
    return cell, store


def recover(backend_root, reference_digest):
    """Rebuild a Store from manifest + chunks + valid WAL prefix; mirrors
    ``Durable::recover``. Returns the recovery stats dict."""
    backend = DiskBackend(backend_root)
    t0 = time.perf_counter()
    manifest = backend.read_manifest() or {"applied": 0, "chunks": []}
    store = Store()
    for h in manifest["chunks"]:
        page = backend.get_chunk(h)
        assert page is not None, "snapshot chunk missing"
        (count,) = struct.unpack_from("<H", page)
        at = 2
        for _ in range(count):
            k, version, last_payload = struct.unpack_from("<QQI", page, at)
            store.data[k] = (version, last_payload)
            at += 20
    store.applied = manifest["applied"]
    records, _consumed = decode_records(backend.read_wal())
    replayed = 0
    for rec in records:
        if rec["index"] <= manifest["applied"]:
            continue  # already captured by the snapshot
        store.put(rec["keys"][0], rec["payload_len"])
        replayed += 1
    dt = time.perf_counter() - t0
    backend.close()
    return {
        "snapshot_applied": manifest["applied"],
        "wal_replayed": replayed,
        "applied": store.applied,
        "recovery_us": round(dt * 1e6),
        "us_per_record": round(dt * 1e6 / replayed, 3) if replayed else 0.0,
        "digest_match": store.digest() == reference_digest,
    }


def recovery_cell(n, snapshot_every, base_dir):
    """Populate a fresh backend with ``n`` Puts (fsync batch 8), then
    time recovery; asserts full-tail replay and digest equality."""
    global SNAPSHOT_EVERY
    root = os.path.join(base_dir, f"recover-{n}-{snapshot_every}")
    saved = SNAPSHOT_EVERY
    SNAPSHOT_EVERY = snapshot_every if snapshot_every else 1 << 62
    keys = [fnv1a64(struct.pack("<Q", i)) % 4096 for i in range(n)]
    _, store = run_cell("disk", 8, keys, root)
    SNAPSHOT_EVERY = saved
    rec = recover(root, store.digest())
    snapshot_applied = rec["snapshot_applied"]
    assert rec["applied"] == n, rec
    assert snapshot_applied + rec["wal_replayed"] == n, (
        f"recovery must account for every flushed record: {rec}")
    assert rec["digest_match"], f"recovered store diverged: {rec}"
    rec["wal_tail"] = n - snapshot_applied
    rec["snapshot_every"] = snapshot_every
    return rec


def torn_tail_check(base_dir):
    """The group-commit legality contract: a torn final record (the crash
    landing mid-write) truncates replay at the last valid frame instead
    of failing recovery."""
    root = os.path.join(base_dir, "torn")
    keys = list(range(100))
    _, store = run_cell("disk", 1, keys, root)
    full = encode_record(101, (0, 101), 101, (0, 101), 1, PAYLOAD, 1, [7])
    with open(os.path.join(root, "wal.log"), "ab") as f:
        f.write(full[: len(full) // 2])  # torn mid-frame
    rec = recover(root, store.digest())
    assert rec["digest_match"], "torn tail must not corrupt recovery"
    assert rec["snapshot_applied"] + rec["wal_replayed"] == 100, rec
    # A CRC flip in the tail truncates there too — never a crash.
    with open(os.path.join(root, "wal.log"), "r+b") as f:
        buf = bytearray(f.read())
        if len(buf) > 20:
            buf[12] ^= 0x40  # body byte of some record past the snapshot cut
            f.seek(0)
            f.write(buf)
    recover(root, store.digest())  # must not raise


def main():
    print(f"--- durability bench (python, real file I/O, "
          f"{OPS} ops, {PAYLOAD} B payload{', SMOKE' if SMOKE else ''}) ---")
    assert zlib.crc32(b"123456789") == 0xCBF43926  # same IEEE CRC as wal.rs

    base_dir = tempfile.mkdtemp(prefix="tempo-bench-durability-")
    try:
        keys = zipf_keys(0.5, OPS, seed=11)
        cells = []
        cell, _ = run_cell("memory", 1, keys, os.path.join(base_dir, "mem"))
        cells.append(cell)
        for batch in (1, 8, 64):
            cell, _ = run_cell("disk", batch, keys, os.path.join(base_dir, f"disk-{batch}"))
            cells.append(cell)
        for c in cells:
            print(f"{c['mode']:>6} fsync_batch={c['fsync_batch']:<3}: "
                  f"{c['ops_per_s_wall']:>9} ops/s, {c['physical_bytes']:>10} B, "
                  f"amp {c['write_amp']:.2f}x, {c['fsyncs']} fsyncs, "
                  f"{c['snapshots']} snapshots")
        mem_rate = cells[0]["ops_per_s_wall"]
        disk_rate = min(c["ops_per_s_wall"] for c in cells[1:])
        slowdown = mem_rate / disk_rate
        max_amp = max(c["write_amp"] for c in cells if c["mode"] == "disk")
        assert max_amp <= 3.0, f"write amplification {max_amp} over the 3x budget"
        print(f"worst disk cell vs memory: {slowdown:.2f}x slower, amp {max_amp:.2f}x")

        tails = [500, 2_000] if SMOKE else [1_000, 10_000, 50_000]
        recoveries = [recovery_cell(n, 0, base_dir) for n in tails]
        recoveries.append(recovery_cell(tails[-1], 4_096, base_dir))
        for r in recoveries:
            print(f"recover: snapshot@{r['snapshot_every'] or '-':<5} + "
                  f"{r['wal_tail']:>6}-record tail -> {r['recovery_us']:>8} us "
                  f"({r['us_per_record']:.2f} us/record), digest match")

        torn_tail_check(base_dir)
        print("torn-tail + CRC-corruption recovery: OK")
    finally:
        shutil.rmtree(base_dir, ignore_errors=True)

    if SMOKE:
        print("durability bench: smoke OK (JSON not rewritten)")
        return

    out = {
        "bench": "durability",
        "workload": f"zipf theta=0.5 over {N_KEYS} keys, {OPS} single-key Puts, "
                    f"{PAYLOAD} B payload; WAL framing byte-identical to wal.rs, "
                    f"snapshots every {SNAPSHOT_EVERY} ops as content-addressed "
                    f"64-entry pages; real write/fsync syscalls in a temp dir",
        "write_amp_disk_max": max_amp,
        "disk_slowdown_vs_memory": round(slowdown, 3),
        "harness": "python (python/bench/bench_durability.py)",
        "cells": cells,
        "recovery": [{k: r[k] for k in ("wal_tail", "snapshot_every", "applied",
                                        "snapshot_applied", "wal_replayed",
                                        "recovery_us", "us_per_record",
                                        "digest_match")} for r in recoveries],
        "regenerate": "python3 python/bench/bench_durability.py "
                      "(or: cargo bench --bench durability)",
    }
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "..", "BENCH_durability.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"durability baseline written to {os.path.normpath(path)}")


if __name__ == "__main__":
    main()
