"""Scan vs incremental stability watermark, measured via a Python port.

Faithful port of the Rust microbenchmark's hot loop
(rust/benches/microbench.rs::stability_watermark_bench): one promise
delta + one majority-watermark query per iteration over r=5 sources at
majority 3. ``scan`` re-collects and sorts every source frontier on each
query (the seed's behaviour, PromiseStore::stable_watermark); the
``incremental`` path updates a cached majority frontier on deltas
(QuorumFrontier) and reads it in O(1).

The container this repo grows in has no Rust toolchain, so the absolute
ns/iter here are Python numbers — the *ratio* is the algorithmic
scan-vs-incremental comparison, measured for real on this machine.
``cargo bench --bench microbench`` overwrites this file with the Rust
numbers when a toolchain is available.

Run from anywhere: ``python3 python/bench/bench_stability.py``.
``--smoke`` (or ``SMOKE=1``) runs a fast regression pass at reduced
iteration counts without overwriting the recorded BENCH_stability.json
(for cargo-less CI).
"""

import json
import os
import sys
import time

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"
R, MAJORITY, ITERS = 5, 3, (20_000 if SMOKE else 200_000)


class SourceTracker:
    """Contiguous watermark + sparse overflow (protocol/common/stability.rs)."""

    def __init__(self):
        self.watermark = 0
        self.above = set()

    def add(self, u):
        if u <= self.watermark:
            return
        if u == self.watermark + 1:
            self.watermark = u
            while self.watermark + 1 in self.above:
                self.above.discard(self.watermark + 1)
                self.watermark += 1
        else:
            self.above.add(u)


def scan_watermark(trackers):
    """The seed path: collect + sort every frontier per query."""
    frontiers = sorted(t.watermark for t in trackers)
    return frontiers[len(frontiers) - MAJORITY]


class QuorumFrontier:
    """Incrementally maintained majority watermark."""

    def __init__(self, n, majority):
        self.frontiers = [0] * n
        self.majority = majority
        self.watermark = 0

    def update(self, source, frontier):
        if frontier <= self.frontiers[source]:
            return False
        self.frontiers[source] = frontier
        w = sorted(self.frontiers)[len(self.frontiers) - self.majority]
        if w > self.watermark:
            self.watermark = w
            return True
        return False


def bench_scan():
    trackers = [SourceTracker() for _ in range(R)]
    start = time.perf_counter()
    for i in range(1, ITERS + 1):
        trackers[i % R].add(i)
        scan_watermark(trackers)
    el = time.perf_counter() - start
    return el / ITERS * 1e9, scan_watermark(trackers)


def bench_incremental():
    trackers = [SourceTracker() for _ in range(R)]
    q = QuorumFrontier(R, MAJORITY)
    start = time.perf_counter()
    for i in range(1, ITERS + 1):
        t = trackers[i % R]
        t.add(i)
        q.update(i % R, t.watermark)
        _ = q.watermark  # the O(1) read
    el = time.perf_counter() - start
    return el / ITERS * 1e9, q.watermark


def check_speedup_threshold():
    """``--check-speedup X``: fail (exit 1) if the freshly measured
    scan/incremental ratio drops below X — the CI regression gate runs
    this in smoke mode so the gate reflects *this* machine, not just the
    recorded baseline (which check_bench.py validates separately)."""
    args = sys.argv[1:]
    if "--check-speedup" not in args:
        return None
    return float(args[args.index("--check-speedup") + 1])


def main():
    scan_ns, scan_wm = bench_scan()
    inc_ns, inc_wm = bench_incremental()
    assert scan_wm == inc_wm, (scan_wm, inc_wm)
    threshold = check_speedup_threshold()
    if threshold is not None and scan_ns / inc_ns < threshold:
        print(
            f"SPEEDUP GATE FAILED: measured {scan_ns / inc_ns:.2f}x "
            f"< required {threshold}x"
        )
        sys.exit(1)
    result = {
        "bench": "stability_watermark",
        "unit": "ns_per_iter",
        "harness": "python port (python/bench/bench_stability.py); no Rust "
        "toolchain in this container — absolute numbers are Python-speed, "
        "the scan-vs-incremental ratio is the algorithmic comparison. "
        "`cargo bench --bench microbench` overwrites this file with Rust "
        "numbers",
        "workload": f"add 1 promise + query majority watermark, r={R}, "
        f"majority={MAJORITY}, {ITERS} iters",
        "scan_ns_per_iter": round(scan_ns, 1),
        "incremental_ns_per_iter": round(inc_ns, 1),
        "speedup": round(scan_ns / inc_ns, 2),
        "regenerate": "cargo bench --bench microbench",
    }
    if SMOKE:
        print(json.dumps(result, indent=2))
        print("smoke mode: BENCH_stability.json left untouched")
        return
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(os.path.join(root, "BENCH_stability.json"))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"written to {path}")


if __name__ == "__main__":
    main()
