"""Bench-regression gate for CI (ROADMAP PR 1 item: "wire a CI regression
gate on the speedup").

Validates the recorded BENCH_*.json baselines at the repo root:

- BENCH_stability.json: the scan-vs-incremental stability watermark
  speedup must be at least ``--min-stability-speedup`` (default 1.5) —
  the PR 1 optimization must not regress, whichever harness (Rust or the
  Python port) recorded the file.
- BENCH_workers.json: must exist with ops/s and allocations-per-op for
  workers 1, 2 and 4 under both contention levels.
- BENCH_batching.json: must exist with both throughput numbers.
- BENCH_reads.json: stability-powered local reads must pay ~zero wire
  bytes (``wire_bytes_per_local_read < 1``) and beat the write-path
  baseline by at least ``--min-read-speedup`` (default 5.0), with mix
  cells recorded for both the 95/5 and 50/50 read mixes and every read
  served locally (``local_reads > 0``), whichever harness (Rust or the
  Python port) recorded the file.
- BENCH_faults.json: the fault path must recover — all three phases
  (healthy, degraded, post_eviction) recorded with positive ops/s,
  post-eviction throughput at least half of healthy, retransmissions
  observed while a quorum peer is dead, the eviction vote recorded
  (epoch 1 installed over real MEpoch frames), every failover re-issue
  absorbed by the dedup window, and the GC info-record backlog pruned
  below its frozen peak once the victim leaves the frontier.
- BENCH_wire.json: the encode-once fan-out must stay O(1) — for every
  message shape, ``encode_once_allocs_per_op`` at fan-out 8 must be at
  most fan-out 1 + 2 (an O(1) slack), and ``encode_once_ns_per_op`` at
  fan-out 8 must not exceed 2x fan-out 1 (flat serialize cost), while
  the recorded legacy path documents the fan-out-proportional cost the
  runtime no longer pays.
- BENCH_durability.json: the WAL + snapshot write path must keep
  write amplification at or under 3x per disk cell (the CRC framing and
  dot/ts headers are the only overhead — payload bytes are journaled
  once), and every recovery cell must replay the full WAL tail
  (``snapshot_applied + wal_replayed == applied``) and rebuild a store
  whose digest matches the pre-crash one (``digest_match``), including
  at least one cell where a snapshot shortened the tail.
- BENCH_batching.json ``tcp`` section: over a real loopback TCP socket
  pair, batched framing must be at least as fast as unbatched
  (``batched_msgs_per_s >= unbatched_msgs_per_s``) — the syscall/frame
  reduction is the whole point of the batcher.
- BENCH_clients.json: the event-loop client plane must hold its cost
  flat as the session table grows — ops/s at 10k sessions at least
  0.8x ops/s at 1k sessions on the same fixed loop pool — every sweep
  cell must batch replies (``replies_per_flush > 1``), and the
  admission-control cell must have shed (``busy_shed > 0``) while
  completing every burst command exactly once. The real-TCP companion
  BENCH_clients_tcp.json (examples/e2e_cluster.rs --sweep-clients) is
  gated the same way when present (it needs a Rust toolchain and a
  raised fd limit to regenerate).

Exit code 0 = all gates pass; 1 = a gate failed (CI turns red).
Run from anywhere: ``python3 python/bench/check_bench.py``.
"""

import json
import os
import sys


def root_path(name):
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(os.path.join(here, "..", "..", name))


def load(name):
    with open(root_path(name)) as f:
        return json.load(f)


def fail(msg):
    print(f"BENCH GATE FAILED: {msg}")
    sys.exit(1)


def main():
    min_speedup = 1.5
    min_read_speedup = 5.0
    args = sys.argv[1:]
    if "--min-stability-speedup" in args:
        min_speedup = float(args[args.index("--min-stability-speedup") + 1])
    if "--min-read-speedup" in args:
        min_read_speedup = float(args[args.index("--min-read-speedup") + 1])

    stability = load("BENCH_stability.json")
    speedup = float(stability.get("speedup", 0.0))
    if speedup < min_speedup:
        fail(
            f"BENCH_stability.json speedup {speedup} < {min_speedup} — the "
            "incremental stability watermark regressed"
        )
    print(f"stability: speedup {speedup} >= {min_speedup} ok")

    workers = load("BENCH_workers.json")
    cells = workers.get("cells", [])
    seen = {(c.get("workers"), c.get("contention")) for c in cells}
    for w in (1, 2, 4):
        for contention in ("low", "high"):
            if (w, contention) not in seen:
                fail(f"BENCH_workers.json missing cell workers={w} {contention}")
    for c in cells:
        ops_key = next(
            (k for k in ("ops_per_s_wall", "ops_per_s_single_thread") if k in c),
            None,
        )
        if ops_key is None or float(c[ops_key]) <= 0:
            fail(f"BENCH_workers.json cell {c} lacks a positive ops/s measurement")
        if "allocs_per_op" not in c:
            fail(f"BENCH_workers.json cell {c} lacks allocs_per_op")
    print(f"workers: {len(cells)} cells with ops/s and allocs/op ok")

    wire = load("BENCH_wire.json")
    msgs = wire.get("messages", [])
    if not msgs:
        fail("BENCH_wire.json has no message cells")
    for m in msgs:
        cells = {c["fanout"]: c for c in m.get("fanout_cells", [])}
        for fanout in (1, 4, 8):
            if fanout not in cells:
                fail(f"BENCH_wire.json {m.get('msg')} missing fanout={fanout}")
        a1 = float(cells[1]["encode_once_allocs_per_op"])
        a8 = float(cells[8]["encode_once_allocs_per_op"])
        if a8 > a1 + 2.0:
            fail(
                f"BENCH_wire.json {m['msg']}: encode-once allocs/op grew with "
                f"fan-out ({a1} -> {a8}) — the shared-body path regressed"
            )
        n1 = float(cells[1]["encode_once_ns_per_op"])
        n8 = float(cells[8]["encode_once_ns_per_op"])
        if n8 > 2.0 * n1:
            fail(
                f"BENCH_wire.json {m['msg']}: encode-once ns/op not flat "
                f"({n1} -> {n8} across fan-out 1 -> 8)"
            )
    print(f"wire: {len(msgs)} message shapes, encode-once flat across fan-out ok")

    batching = load("BENCH_batching.json")
    if "unbatched_ops_per_s" in batching:
        # Rust harness schema (cargo bench --bench microbench).
        for field in ("unbatched_ops_per_s", "batched_ops_per_s"):
            if float(batching.get(field, 0.0)) <= 0:
                fail(f"BENCH_batching.json lacks {field}")
        ratio = batching["batched_ops_per_s"] / batching["unbatched_ops_per_s"]
        if ratio < 1.0:
            fail(f"BENCH_batching.json batched/unbatched throughput {ratio:.2f} < 1")
    else:
        # Python-port schema: batching must still reduce frames.
        reduction = float(batching.get("frame_reduction", 0.0))
        if reduction < 1.5:
            fail(f"BENCH_batching.json frame_reduction {reduction} < 1.5")
    tcp = batching.get("tcp", {})
    if tcp:
        unb = float(tcp.get("unbatched_msgs_per_s", 0.0))
        bat = float(tcp.get("batched_msgs_per_s", 0.0))
        if unb <= 0 or bat <= 0:
            fail("BENCH_batching.json tcp section lacks positive msgs/s")
        if bat < unb:
            fail(
                f"BENCH_batching.json batched {bat:.0f} msgs/s < unbatched "
                f"{unb:.0f} over real TCP — frame coalescing regressed"
            )
        print(f"batching: tcp {bat / unb:.2f}x ok")
    else:
        print("batching: ok (no tcp section recorded)")
    # The Rust e2e harness (examples/e2e_cluster.rs --bench-batching)
    # records the same comparison over a real 3-node cluster; gate it
    # when the file exists (it needs a Rust toolchain to regenerate).
    if os.path.exists(root_path("BENCH_batching_tcp.json")):
        e2e = load("BENCH_batching_tcp.json")
        ratio = float(e2e.get("batched_vs_unbatched_ops_ratio", 0.0))
        if ratio < 1.0:
            fail(
                f"BENCH_batching_tcp.json batched/unbatched ratio {ratio} < 1 "
                "— batching cost throughput over the real cluster"
            )
        print(f"batching e2e tcp: ratio {ratio:.2f} >= 1 ok")

    clients = load("BENCH_clients.json")
    c_cells = {c.get("sessions"): c for c in clients.get("cells", [])}
    for sessions in (1_000, 10_000):
        if sessions not in c_cells:
            fail(f"BENCH_clients.json missing cell sessions={sessions}")
    for c in c_cells.values():
        if float(c.get("ops_per_s", 0.0)) <= 0:
            fail(f"BENCH_clients.json cell {c} lacks a positive ops/s")
        if float(c.get("replies_per_flush", 0.0)) <= 1.0:
            fail(
                f"BENCH_clients.json cell sessions={c.get('sessions')} "
                f"replies_per_flush {c.get('replies_per_flush')} <= 1 — the "
                "event loop stopped batching replies per wakeup"
            )
    c_ratio = c_cells[10_000]["ops_per_s"] / c_cells[1_000]["ops_per_s"]
    if c_ratio < 0.8:
        fail(
            f"BENCH_clients.json 10k/1k sessions ops/s ratio {c_ratio:.2f} < "
            "0.8 — per-op cost grew with the session table (the loop must "
            "pay per event, not per connection)"
        )
    c_busy = clients.get("busy", {})
    if int(c_busy.get("busy_shed", 0)) <= 0:
        fail("BENCH_clients.json admission control never shed — busy_shed == 0")
    if int(c_busy.get("completed", 0)) != int(c_busy.get("burst", -1)):
        fail(
            f"BENCH_clients.json busy cell completed {c_busy.get('completed')} "
            f"of {c_busy.get('burst')} — sheds lost or duplicated commands"
        )
    print(
        f"clients: 10k/1k ratio {c_ratio:.2f} >= 0.8, replies/flush > 1 in "
        f"{len(c_cells)} cells, {c_busy['busy_shed']} busy sheds ok"
    )
    # The Rust e2e harness (examples/e2e_cluster.rs --sweep-clients)
    # records the same sweep over real TCP sockets; gate it when the
    # file exists (needs a Rust toolchain + ulimit -n 65536).
    if os.path.exists(root_path("BENCH_clients_tcp.json")):
        e2e = load("BENCH_clients_tcp.json")
        t_cells = {c.get("sessions"): c for c in e2e.get("cells", [])}
        for sessions, c in t_cells.items():
            if int(c.get("client_connections", 0)) != sessions:
                fail(
                    f"BENCH_clients_tcp.json sessions={sessions} counted "
                    f"{c.get('client_connections')} event-loop connections — "
                    "sessions leaked off the event-loop plane"
                )
            if sessions >= 10_000 and float(c.get("replies_per_flush", 0.0)) <= 1.0:
                fail(
                    f"BENCH_clients_tcp.json sessions={sessions} "
                    "replies_per_flush <= 1 over real TCP"
                )
        t_ratio = float(e2e.get("ratio_10k_vs_1k_ops", 0.0))
        if t_ratio < 0.8:
            fail(
                f"BENCH_clients_tcp.json 10k/1k ops ratio {t_ratio:.2f} < 0.8 "
                "over real TCP"
            )
        t_busy = e2e.get("busy", {})
        if int(t_busy.get("shed_at_edge", 0)) <= 0:
            fail("BENCH_clients_tcp.json admission control never shed")
        print(f"clients e2e tcp: ratio {t_ratio:.2f} >= 0.8, sheds observed ok")

    durability = load("BENCH_durability.json")
    d_cells = durability.get("cells", [])
    disk_cells = [c for c in d_cells if c.get("mode") == "disk"]
    if not disk_cells:
        fail("BENCH_durability.json has no disk cells")
    if not any(c.get("mode") == "memory" for c in d_cells):
        fail("BENCH_durability.json has no in-memory baseline cell")
    for c in disk_cells:
        amp = float(c.get("write_amp", 1e9))
        if amp > 3.0:
            fail(
                f"BENCH_durability.json disk cell fsync_batch="
                f"{c.get('fsync_batch')} write_amp {amp} > 3.0 — the WAL/"
                "snapshot framing overhead regressed"
            )
        if float(c.get("ops_per_s_wall", 0.0)) <= 0 or int(c.get("fsyncs", 0)) <= 0:
            fail(f"BENCH_durability.json disk cell {c} lacks ops/s or fsyncs")
    recoveries = durability.get("recovery", [])
    if not recoveries:
        fail("BENCH_durability.json has no recovery cells")
    for r in recoveries:
        if not r.get("digest_match"):
            fail(f"BENCH_durability.json recovery cell {r} diverged from the pre-crash store")
        applied = int(r.get("applied", 0))
        accounted = int(r.get("snapshot_applied", 0)) + int(r.get("wal_replayed", 0))
        if applied <= 0 or accounted != applied:
            fail(
                f"BENCH_durability.json recovery cell {r} did not replay the "
                f"full WAL tail ({accounted} accounted for {applied} applied)"
            )
        if float(r.get("recovery_us", 0.0)) <= 0:
            fail(f"BENCH_durability.json recovery cell {r} lacks a recovery time")
    if not any(int(r.get("snapshot_applied", 0)) > 0 for r in recoveries):
        fail("BENCH_durability.json has no recovery cell where a snapshot shortened the tail")
    max_amp = max(float(c["write_amp"]) for c in disk_cells)
    print(
        f"durability: write amp {max_amp:.2f}x <= 3.0, "
        f"{len(recoveries)} recovery cells replay fully with matching digests ok"
    )

    reads = load("BENCH_reads.json")
    read_speedup = float(reads.get("read_speedup_vs_write_path", 0.0))
    if read_speedup < min_read_speedup:
        fail(
            f"BENCH_reads.json read_speedup_vs_write_path {read_speedup} < "
            f"{min_read_speedup} — local reads no longer beat the ordering path"
        )
    read_bytes = float(reads.get("wire_bytes_per_local_read", 1e9))
    if read_bytes >= 1.0:
        fail(
            f"BENCH_reads.json wire_bytes_per_local_read {read_bytes} >= 1 — "
            "a local read must not touch the wire"
        )
    if float(reads.get("local_read_ops_per_s", 0.0)) <= 0:
        fail("BENCH_reads.json lacks a positive local_read_ops_per_s")
    read_cells = reads.get("cells", [])
    seen = {c.get("read_pct") for c in read_cells}
    for pct in (95, 50):
        if pct not in seen:
            fail(f"BENCH_reads.json missing mix cell read_pct={pct}")
    for c in read_cells:
        if float(c.get("ops_per_s_wall", 0.0)) <= 0:
            fail(f"BENCH_reads.json cell {c} lacks a positive ops/s measurement")
        if int(c.get("local_reads", 0)) <= 0:
            fail(f"BENCH_reads.json cell {c} served no local reads")
    print(
        f"reads: speedup {read_speedup} >= {min_read_speedup}, "
        f"{read_bytes} wire B/read, {len(read_cells)} mix cells ok"
    )

    faults = load("BENCH_faults.json")
    phases = {p.get("phase"): p for p in faults.get("phases", [])}
    for name in ("healthy", "degraded", "post_eviction"):
        if name not in phases:
            fail(f"BENCH_faults.json missing phase {name}")
        if float(phases[name].get("ops_per_s_wall", 0.0)) <= 0:
            fail(f"BENCH_faults.json phase {name} lacks a positive ops/s")
    healthy = float(phases["healthy"]["ops_per_s_wall"])
    recovered = float(phases["post_eviction"]["ops_per_s_wall"])
    if recovered < 0.5 * healthy:
        fail(
            f"BENCH_faults.json post-eviction throughput {recovered} < half "
            f"of healthy {healthy} — the cluster did not recover"
        )
    if int(phases["degraded"].get("retransmits", 0)) <= 0:
        fail(
            "BENCH_faults.json degraded phase saw no retransmits — the "
            "dead quorum peer was never re-driven"
        )
    recovery = faults.get("recovery", {})
    if int(recovery.get("epoch_installed", 0)) < 1 or not recovery.get("evicted"):
        fail("BENCH_faults.json recovery did not install an eviction epoch")
    if int(recovery.get("epoch_frames", 0)) <= 0:
        fail("BENCH_faults.json records no MEpoch frames for the vote")
    if float(recovery.get("time_to_reconfigure_ms", 0.0)) <= 0:
        fail("BENCH_faults.json lacks a positive time_to_reconfigure_ms")
    reissues = int(recovery.get("failover_reissues", 0))
    if reissues <= 0 or int(recovery.get("dedup_hits", 0)) < reissues:
        fail(
            "BENCH_faults.json failover re-issues were not all absorbed by "
            f"the dedup window ({recovery.get('dedup_hits')} hits for "
            f"{reissues} re-issues)"
        )
    gc = recovery.get("gc_info_records", {})
    frozen = int(gc.get("peak_frozen", 0))
    after = int(gc.get("after_unfreeze", frozen))
    if frozen <= 0 or after >= frozen:
        fail(
            f"BENCH_faults.json eviction did not unfreeze GC (info records "
            f"{frozen} frozen -> {after} after)"
        )
    stalled_dots = recovery.get("stalled_dots", {})
    stalled = int(stalled_dots.get("stalled", 0))
    redriven = int(stalled_dots.get("recovered_to_commit", -1))
    if stalled <= 0:
        fail(
            "BENCH_faults.json recorded no stalled victim coordinations — "
            "the ballot-takeover path was never exercised"
        )
    if redriven != stalled:
        fail(
            f"BENCH_faults.json stalled dots left uncommitted after the "
            f"ballot takeover ({redriven}/{stalled} re-driven)"
        )
    if int(stalled_dots.get("rec_frames", 0)) <= 0:
        fail(
            "BENCH_faults.json records no MRec/MRecAck frames for the "
            "stalled-dot recovery"
        )
    print(
        f"faults: recovered {recovered:.0f}/{healthy:.0f} ops/s, "
        f"{phases['degraded']['retransmits']} retransmits, epoch "
        f"{recovery['epoch_installed']} evicting {recovery['evicted']}, "
        f"gc {frozen} -> {after}, {redriven}/{stalled} stalled dots "
        f"re-driven ok"
    )
    print("all bench gates passed")


if __name__ == "__main__":
    main()
