"""Python port of the Tempo wire codec (rust/src/net/wire.rs).

Byte-for-byte faithful to docs/WIRE.md: little-endian fixed-width
integers, u8 message tags, length-prefixed ``MBatch`` members, and the
client service frames (``ClientSubmit`` tag 17, carrying the session's
read floor / ``ClientReply`` tag 18, carrying the decided timestamp /
``ClientBusy`` tag 25, the admission-control shed) and the
state-transfer frames (``ManifestRequest`` tag 22 /
``ManifestReply`` tag 23 / ``Chunk`` tag 24). Used by
``bench_batching.py`` to measure framing amortization on this machine
and as an executable cross-check of the WIRE.md spec: every frame
produced here must decode to the same message, and malformed frames must
raise ``WireError`` (mirroring the Rust codec returning ``Err`` — never a
panic). The protocol, client and transfer planes are strictly separated:
``decode`` rejects tags 17–18, 22–24 and 25, ``decode_client`` rejects
tags 0–16, 21 and 22–24, ``decode_transfer`` rejects everything at or
below tag 21 plus 25, and an ``MBatch`` member carrying a client or
transfer frame is malformed the same way a nested batch is.

``FrameDecoder`` mirrors the Rust event loop's incremental transport
decoder (``[len u32][from u32][body]``): feed arbitrary byte chunks,
get complete frames out — byte-for-byte equivalent to reading whole
frames, whatever the chunking (the Rust side pins this with
``prop_incremental_decode_matches_whole_frame_decode_on_any_split``).

Messages are dicts with a ``t`` tag key, e.g.::

    {"t": "MStable", "dot": (3, 42)}
    {"t": "MBatch", "msgs": [...]}
    {"t": "ClientReply", "rid": (7, 3), "response": [(1, 4)]}

Dots are ``(origin, seq)`` tuples; rids are ``(client, seq)`` tuples;
commands are dicts with ``rid``, ``op`` (0 Get / 1 Put / 2 Rmw /
3 Read), ``payload_len``, ``batched`` and ``keys`` (the codec
materializes ``payload_len`` zero bytes of payload). Op 3 is the
stability-served local read: same command layout, only the tag differs,
so a read-flagged ``ClientSubmit`` costs exactly as many bytes as a Get.
"""

import struct


class WireError(Exception):
    """Malformed frame (truncated, oversized, bad tag/op/phase, nested batch)."""


PHASES = ["Start", "Payload", "Propose", "RecoverR", "RecoverP", "Commit", "Execute"]


class Writer:
    def __init__(self):
        self.parts = []

    def u8(self, v):
        self.parts.append(struct.pack("<B", v))

    def u16(self, v):
        self.parts.append(struct.pack("<H", v))

    def u32(self, v):
        self.parts.append(struct.pack("<I", v))

    def u64(self, v):
        self.parts.append(struct.pack("<Q", v))

    def dot(self, d):
        self.u32(d[0])
        self.u64(d[1])

    def rid(self, r):
        self.u64(r[0])
        self.u64(r[1])

    def cmd(self, c):
        self.rid(c["rid"])
        self.u8(c["op"])
        self.u32(c["payload_len"])
        self.u32(c["batched"])
        self.u16(len(c["keys"]))
        for k in c["keys"]:
            self.u64(k)
        # Payload contents are irrelevant to ordering: materialized zeros.
        self.parts.append(b"\x00" * c["payload_len"])

    def quorums(self, q):
        self.u8(len(q))
        for shard, procs in q:
            self.u32(shard)
            self.u8(len(procs))
            for p in procs:
                self.u32(p)

    def key_ts(self, ts):
        self.u16(len(ts))
        for k, t in ts:
            self.u64(k)
            self.u64(t)

    def promise_set(self, ps):
        detached, attached = ps
        self.u16(len(detached))
        for lo, hi in detached:
            self.u64(lo)
            self.u64(hi)
        self.u16(len(attached))
        for d, t in attached:
            self.dot(d)
            self.u64(t)

    def key_promises(self, kp):
        self.u16(len(kp))
        for k, ps in kp:
            self.u64(k)
            self.promise_set(ps)

    def bytes(self):
        return b"".join(self.parts)


class Reader:
    def __init__(self, buf):
        self.buf = buf
        self.pos = 0

    def take(self, n):
        if self.pos + n > len(self.buf):
            raise WireError(
                f"truncated frame at {self.pos} + {n} > {len(self.buf)}"
            )
        s = self.buf[self.pos : self.pos + n]
        self.pos += n
        return s

    def u8(self):
        return self.take(1)[0]

    def u16(self):
        return struct.unpack("<H", self.take(2))[0]

    def u32(self):
        return struct.unpack("<I", self.take(4))[0]

    def u64(self):
        return struct.unpack("<Q", self.take(8))[0]

    def dot(self):
        return (self.u32(), self.u64())

    def rid(self):
        return (self.u64(), self.u64())

    def cmd(self):
        rid = self.rid()
        op = self.u8()
        if op > 3:
            raise WireError(f"bad op tag {op}")
        payload_len = self.u32()
        batched = self.u32()
        keys = [self.u64() for _ in range(self.u16())]
        self.take(payload_len)  # skip the materialized payload, checked
        return {
            "rid": rid,
            "op": op,
            "payload_len": payload_len,
            "batched": batched,
            "keys": keys,
        }

    def quorums(self):
        return [
            (self.u32(), [self.u32() for _ in range(self.u8())])
            for _ in range(self.u8())
        ]

    def key_ts(self):
        return [(self.u64(), self.u64()) for _ in range(self.u16())]

    def promise_set(self):
        detached = [(self.u64(), self.u64()) for _ in range(self.u16())]
        attached = [(self.dot(), self.u64()) for _ in range(self.u16())]
        return (detached, attached)

    def key_promises(self):
        return [(self.u64(), self.promise_set()) for _ in range(self.u16())]


def encode(msg):
    """Encode one message (frame body, without the runtime's length prefix)."""
    w = Writer()
    t = msg["t"]
    if t == "MSubmit":
        w.u8(0), w.dot(msg["dot"]), w.cmd(msg["cmd"]), w.quorums(msg["quorums"])
    elif t == "MPropose":
        w.u8(1), w.dot(msg["dot"]), w.cmd(msg["cmd"]), w.quorums(msg["quorums"])
        w.key_ts(msg["ts"])
    elif t == "MProposeAck":
        w.u8(2), w.dot(msg["dot"]), w.key_ts(msg["ts"])
        w.key_promises(msg["promises"])
    elif t == "MPayload":
        w.u8(3), w.dot(msg["dot"]), w.cmd(msg["cmd"]), w.quorums(msg["quorums"])
    elif t == "MCommit":
        w.u8(4), w.dot(msg["dot"]), w.u32(msg["group"]), w.key_ts(msg["ts"])
        w.u16(len(msg["promises"]))
        for p, kp in msg["promises"]:
            w.u32(p)
            w.key_promises(kp)
    elif t == "MCommitDirect":
        w.u8(5), w.dot(msg["dot"]), w.cmd(msg["cmd"]), w.quorums(msg["quorums"])
        w.u64(msg["final_ts"])
    elif t == "MConsensus":
        w.u8(6), w.dot(msg["dot"]), w.key_ts(msg["ts"]), w.u64(msg["bal"])
    elif t == "MConsensusAck":
        w.u8(7), w.dot(msg["dot"]), w.u64(msg["bal"])
    elif t == "MPromises":
        w.u8(8), w.key_promises(msg["promises"])
    elif t == "MBump":
        w.u8(9), w.dot(msg["dot"]), w.u64(msg["ts"])
    elif t == "MStable":
        w.u8(10), w.dot(msg["dot"])
    elif t == "MRec":
        w.u8(11), w.dot(msg["dot"]), w.u64(msg["bal"])
    elif t == "MRecAck":
        w.u8(12), w.dot(msg["dot"]), w.key_ts(msg["ts"])
        w.u8(PHASES.index(msg["phase"]))
        w.u64(msg["abal"]), w.u64(msg["bal"])
    elif t == "MRecNAck":
        w.u8(13), w.dot(msg["dot"]), w.u64(msg["bal"])
    elif t == "MCommitRequest":
        w.u8(14), w.dot(msg["dot"])
    elif t == "MGarbageCollect":
        w.u8(15)
        w.u16(len(msg["executed"]))
        for p, wm in msg["executed"]:
            w.u32(p)
            w.u64(wm)
    elif t == "MBatch":
        w.u8(16)
        w.u16(len(msg["msgs"]))
        for m in msg["msgs"]:
            body = encode(m)
            w.u32(len(body))
            w.parts.append(body)
    elif t == "MEpoch":
        w.u8(21)
        w.u64(msg["epoch"])
        w.u16(len(msg["evicted"]))
        for p in msg["evicted"]:
            w.u32(p)
    else:
        raise ValueError(f"unknown message {t}")
    return w.bytes()


def encode_client(frame):
    """Encode a client frame (tags 17–18, 25; without the length prefix).

    ``ClientSubmit`` carries the session's read floor (u64, trailing) —
    the lowest stability timestamp a failover read may serve at;
    ``ClientReply`` carries the decided ordering timestamp (u64,
    trailing) the session folds into that floor after a write;
    ``ClientBusy`` carries only the shed request's rid — the node's
    admission control rejected the submit at the edge (retryable).
    """
    w = Writer()
    t = frame["t"]
    if t == "ClientSubmit":
        w.u8(17), w.cmd(frame["cmd"]), w.u64(frame["floor"])
    elif t == "ClientReply":
        w.u8(18), w.rid(frame["rid"])
        w.u16(len(frame["response"]))
        for k, v in frame["response"]:
            w.u64(k)
            w.u64(v)
        w.u64(frame["ts"])
    elif t == "ClientBusy":
        w.u8(25), w.rid(frame["rid"])
    else:
        raise ValueError(f"unknown client frame {t}")
    return w.bytes()


def decode_client(buf):
    """Decode a client frame; a protocol tag (0–16, 21) or a transfer
    tag (22–24) here is an error."""
    r = Reader(buf)
    tag = r.u8()
    if tag == 17:
        cmd = r.cmd()
        return {"t": "ClientSubmit", "cmd": cmd, "floor": r.u64()}
    if tag == 18:
        rid = r.rid()
        response = [(r.u64(), r.u64()) for _ in range(r.u16())]
        return {"t": "ClientReply", "rid": rid, "response": response, "ts": r.u64()}
    if tag == 25:
        return {"t": "ClientBusy", "rid": r.rid()}
    if tag <= 16 or tag == 21:
        raise WireError(f"protocol frame tag {tag} in client stream")
    if 22 <= tag <= 24:
        raise WireError(f"transfer frame tag {tag} in client stream")
    raise WireError(f"bad client frame tag {tag}")


def encode_transfer(frame):
    """Encode a state-transfer frame (tags 22–24, docs/WIRE.md):

    - ``ManifestRequest``: ``[22][slot u32]``
    - ``ManifestReply``: ``[23][slot u32][applied u64][n u32][n x hash
      u64][f u16][f x (origin u32, floor u64)][dlen u32][dedup bytes]``
    - ``Chunk``: ``[24][slot u32][hash u64][present u8][len u32][data]``
    """
    w = Writer()
    t = frame["t"]
    if t == "ManifestRequest":
        w.u8(22), w.u32(frame["slot"])
    elif t == "ManifestReply":
        w.u8(23), w.u32(frame["slot"]), w.u64(frame["applied"])
        w.u32(len(frame["chunks"]))
        for h in frame["chunks"]:
            w.u64(h)
        w.u16(len(frame["dot_floors"]))
        for p, floor in frame["dot_floors"]:
            w.u32(p)
            w.u64(floor)
        w.u32(len(frame["dedup"]))
        w.parts.append(bytes(frame["dedup"]))
    elif t == "Chunk":
        w.u8(24), w.u32(frame["slot"]), w.u64(frame["hash"])
        w.u8(1 if frame["present"] else 0)
        w.u32(len(frame["data"]))
        w.parts.append(bytes(frame["data"]))
    else:
        raise ValueError(f"unknown transfer frame {t}")
    return w.bytes()


def decode_transfer(buf):
    """Decode a state-transfer frame (tags 22–24). Any other plane's tag
    — protocol, client, routed, merged — is an error: the transfer plane
    is as strictly separated as the others."""
    r = Reader(buf)
    tag = r.u8()
    if tag == 22:
        return {"t": "ManifestRequest", "slot": r.u32()}
    if tag == 23:
        slot, applied = r.u32(), r.u64()
        chunks = [r.u64() for _ in range(r.u32())]
        dot_floors = [(r.u32(), r.u64()) for _ in range(r.u16())]
        dedup = r.take(r.u32())
        return {
            "t": "ManifestReply",
            "slot": slot,
            "applied": applied,
            "chunks": chunks,
            "dot_floors": dot_floors,
            "dedup": dedup,
        }
    if tag == 24:
        slot, hash_ = r.u32(), r.u64()
        present = r.u8()
        if present > 1:
            raise WireError(f"bad chunk present byte {present}")
        data = r.take(r.u32())
        return {
            "t": "Chunk",
            "slot": slot,
            "hash": hash_,
            "present": present == 1,
            "data": data,
        }
    if tag <= 21:
        raise WireError(f"non-transfer frame tag {tag} in transfer stream")
    raise WireError(f"bad transfer frame tag {tag}")


def decode(buf):
    """Decode one frame body; raises WireError on malformed input.

    Trailing bytes after a complete top-level message are ignored
    (forward compatibility); inside an ``MBatch`` every member must
    consume its length prefix exactly.
    """
    return _decode_at(Reader(buf))


def _decode_at(r):
    tag = r.u8()
    if tag == 0:
        return {"t": "MSubmit", "dot": r.dot(), "cmd": r.cmd(), "quorums": r.quorums()}
    if tag == 1:
        return {
            "t": "MPropose",
            "dot": r.dot(),
            "cmd": r.cmd(),
            "quorums": r.quorums(),
            "ts": r.key_ts(),
        }
    if tag == 2:
        return {
            "t": "MProposeAck",
            "dot": r.dot(),
            "ts": r.key_ts(),
            "promises": r.key_promises(),
        }
    if tag == 3:
        return {"t": "MPayload", "dot": r.dot(), "cmd": r.cmd(), "quorums": r.quorums()}
    if tag == 4:
        dot, group, ts = r.dot(), r.u32(), r.key_ts()
        promises = [(r.u32(), r.key_promises()) for _ in range(r.u16())]
        return {"t": "MCommit", "dot": dot, "group": group, "ts": ts, "promises": promises}
    if tag == 5:
        return {
            "t": "MCommitDirect",
            "dot": r.dot(),
            "cmd": r.cmd(),
            "quorums": r.quorums(),
            "final_ts": r.u64(),
        }
    if tag == 6:
        return {"t": "MConsensus", "dot": r.dot(), "ts": r.key_ts(), "bal": r.u64()}
    if tag == 7:
        return {"t": "MConsensusAck", "dot": r.dot(), "bal": r.u64()}
    if tag == 8:
        return {"t": "MPromises", "promises": r.key_promises()}
    if tag == 9:
        return {"t": "MBump", "dot": r.dot(), "ts": r.u64()}
    if tag == 10:
        return {"t": "MStable", "dot": r.dot()}
    if tag == 11:
        return {"t": "MRec", "dot": r.dot(), "bal": r.u64()}
    if tag == 12:
        dot, ts, pi = r.dot(), r.key_ts(), r.u8()
        if pi >= len(PHASES):
            raise WireError(f"bad phase tag {pi}")
        return {
            "t": "MRecAck",
            "dot": dot,
            "ts": ts,
            "phase": PHASES[pi],
            "abal": r.u64(),
            "bal": r.u64(),
        }
    if tag == 13:
        return {"t": "MRecNAck", "dot": r.dot(), "bal": r.u64()}
    if tag == 14:
        return {"t": "MCommitRequest", "dot": r.dot()}
    if tag == 15:
        executed = [(r.u32(), r.u64()) for _ in range(r.u16())]
        return {"t": "MGarbageCollect", "executed": executed}
    if tag == 16:
        msgs = []
        for _ in range(r.u16()):
            length = r.u32()
            body = r.take(length)
            # Reject nested batches and client frames by peeking the
            # member tag BEFORE recursing: a deeply nested hostile frame
            # must error, not exhaust the stack, and a client frame can
            # never travel between protocol peers.
            if body[:1] == b"\x10":
                raise WireError("nested MBatch frame")
            if body[:1] in (b"\x11", b"\x12", b"\x19"):
                raise WireError(f"client frame tag {body[0]} inside MBatch")
            if body[:1] == b"\x13":
                raise WireError("routed envelope inside MBatch")
            if body[:1] == b"\x14":
                raise WireError("merged frame inside MBatch")
            if body[:1] in (b"\x16", b"\x17", b"\x18"):
                raise WireError(f"transfer frame tag {body[0]} inside MBatch")
            sub = Reader(body)
            inner = _decode_at(sub)
            if sub.pos != length:
                raise WireError(
                    f"MBatch member declared {length} bytes, used {sub.pos}"
                )
            msgs.append(inner)
        return {"t": "MBatch", "msgs": msgs}
    if tag == 21:
        epoch = r.u64()
        evicted = [r.u32() for _ in range(r.u16())]
        return {"t": "MEpoch", "epoch": epoch, "evicted": evicted}
    if tag in (17, 18, 25):
        raise WireError(f"client frame tag {tag} in protocol stream")
    if tag == 19:
        raise WireError("routed envelope where a bare protocol message was expected")
    if tag == 20:
        raise WireError("merged frame where a bare protocol message was expected")
    if 22 <= tag <= 24:
        raise WireError(f"transfer frame tag {tag} in protocol stream")
    raise WireError(f"bad message tag {tag}")


def encode_routed(worker, msg):
    """Encode the worker-routed envelope (tag 19, docs/WIRE.md):
    ``[19][worker u8][inner msg]`` — what peer connections carry under
    worker sharding."""
    w = Writer()
    w.u8(19)
    w.u8(worker)
    return w.bytes() + encode(msg)


def decode_routed(buf):
    """Decode a worker-routed envelope into ``(worker, msg)``."""
    r = Reader(buf)
    tag = r.u8()
    if tag != 19:
        raise WireError(f"expected routed frame tag 19, got {tag}")
    worker = r.u8()
    return worker, _decode_at(r)


def encode_merged(bodies):
    """Encode the merged transport frame (tag 20, docs/WIRE.md):
    ``[20][n: u16][n x (len: u32, routed envelope bytes)]`` — the
    per-peer outbound merger's frame, coalescing several already-encoded
    routed envelopes bound for one peer. Members are referenced as-is:
    merging never re-serializes (the Rust writer emits these exact bytes
    with one vectored write)."""
    w = Writer()
    w.u8(20)
    w.u16(len(bodies))
    for b in bodies:
        w.u32(len(b))
        w.parts.append(b)
    return w.bytes()


def decode_merged(buf):
    """Decode a merged frame into its ``[(worker, msg), ...]`` members,
    in wire order. Every member must be a routed envelope consuming its
    declared length exactly."""
    r = Reader(buf)
    tag = r.u8()
    if tag != 20:
        raise WireError(f"expected merged frame tag 20, got {tag}")
    members = []
    for _ in range(r.u16()):
        length = r.u32()
        body = r.take(length)
        sub = Reader(body)
        if sub.u8() != 19:
            raise WireError("merged member is not a routed envelope")
        worker = sub.u8()
        msg = _decode_at(sub)
        if sub.pos != length:
            raise WireError(
                f"merged member declared {length} bytes, used {sub.pos}"
            )
        members.append((worker, msg))
    return members


MAX_FRAME_BYTES = 16 << 20


class FrameDecoder:
    """Incremental transport-frame decoder (``[len u32][from u32][body]``),
    mirroring ``rust/src/net/wire.rs FrameDecoder``: feed arbitrary byte
    chunks with :meth:`feed`; it returns ``(consumed, complete)`` and
    stops at each frame boundary. Read the completed frame with
    :attr:`sender`/:attr:`body`, then :meth:`clear` before feeding on.
    Raises ``WireError`` only on a length header above
    ``MAX_FRAME_BYTES`` — a truncated stream just stays incomplete."""

    def __init__(self):
        self.hdr = b""
        self.body = b""
        self.body_len = 0
        self.complete = False

    def feed(self, chunk):
        if self.complete:
            return 0, True
        used = 0
        if len(self.hdr) < 8:
            n = min(8 - len(self.hdr), len(chunk))
            self.hdr += chunk[:n]
            used += n
            if len(self.hdr) < 8:
                return used, False
            length = struct.unpack("<I", self.hdr[0:4])[0]
            if length > MAX_FRAME_BYTES:
                raise WireError(
                    f"frame of {length} bytes exceeds MAX_FRAME_BYTES"
                )
            self.body = b""
            self.body_len = length
            if length == 0:
                self.complete = True
                return used, True
        take = min(self.body_len - len(self.body), len(chunk) - used)
        self.body += chunk[used : used + take]
        used += take
        self.complete = len(self.body) == self.body_len
        return used, self.complete

    @property
    def sender(self):
        return struct.unpack("<I", self.hdr[4:8])[0]

    def clear(self):
        self.hdr = b""
        self.body = b""
        self.body_len = 0
        self.complete = False


def self_check():
    """Round-trip + malformed-input sanity check of the port itself."""
    dot = (3, 42)
    cmd = {"rid": (7, 9), "op": 2, "payload_len": 512, "batched": 1, "keys": [1, 99]}
    ps = ([(1, 5), (7, 9)], [(dot, 10)])
    msgs = [
        {"t": "MSubmit", "dot": dot, "cmd": cmd, "quorums": [(0, [0, 1]), (1, [3])]},
        {"t": "MPropose", "dot": dot, "cmd": cmd, "quorums": [], "ts": [(1, 10)]},
        {"t": "MProposeAck", "dot": dot, "ts": [(1, 10)], "promises": [(1, ps)]},
        {"t": "MCommit", "dot": dot, "group": 1, "ts": [(1, 10)], "promises": [(2, [(1, ps)])]},
        {"t": "MPromises", "promises": [(1, ps), (99, ([], []))]},
        {"t": "MRecAck", "dot": dot, "ts": [], "phase": "Commit", "abal": 1, "bal": 2},
        {"t": "MGarbageCollect", "executed": [(0, 41), (4, 7)]},
        {"t": "MStable", "dot": dot},
        {"t": "MEpoch", "epoch": 3, "evicted": [2, 4]},
        {"t": "MEpoch", "epoch": 0, "evicted": []},
        {"t": "MBatch", "msgs": [{"t": "MStable", "dot": dot}, {"t": "MBump", "dot": dot, "ts": 9}]},
    ]
    for m in msgs:
        assert decode(encode(m)) == m, m
    batch = encode(msgs[-1])
    for cut in range(len(batch)):
        try:
            decode(batch[:cut])
            raise AssertionError(f"truncated frame decoded at cut {cut}")
        except WireError:
            pass
    nested = Writer()
    nested.u8(16), nested.u16(1)
    body = encode({"t": "MBatch", "msgs": []})
    nested.u32(len(body))
    nested.parts.append(body)
    try:
        decode(nested.bytes())
        raise AssertionError("nested batch decoded")
    except WireError:
        pass
    padded = Writer()
    padded.u8(16), padded.u16(1)
    body = encode({"t": "MStable", "dot": dot})
    padded.u32(len(body) + 2)
    padded.parts.append(body)
    padded.u16(0xBEEF)
    try:
        decode(padded.bytes())
        raise AssertionError("padded member decoded")
    except WireError:
        pass
    # Epoch vote (tag 21): a protocol-plane message — truncation at
    # every cut rejects, it is a legal MBatch member (unlike tags
    # 16–20), and it never decodes on the client plane (mirrors the Rust
    # prop_epoch_frames_roundtrip_and_stay_on_the_protocol_plane).
    epoch_msg = {"t": "MEpoch", "epoch": 7, "evicted": [1, 3]}
    enc = encode(epoch_msg)
    assert enc[0] == 21 and len(enc) == 1 + 8 + 2 + 4 * 2, enc
    for cut in range(len(enc)):
        try:
            decode(enc[:cut])
            raise AssertionError(f"truncated epoch vote decoded at {cut}")
        except WireError:
            pass
    try:
        decode_client(enc)
        raise AssertionError("epoch vote decoded on the client plane")
    except WireError:
        pass
    ebatch = Writer()
    ebatch.u8(16), ebatch.u16(1), ebatch.u32(len(enc))
    ebatch.parts.append(enc)
    got = decode(ebatch.bytes())
    assert got == {"t": "MBatch", "msgs": [epoch_msg]}, got
    frame = encode({"t": "MStable", "dot": dot})
    for _ in range(5000):  # depth well past any recursion limit
        deep = Writer()
        deep.u8(16), deep.u16(1), deep.u32(len(frame))
        deep.parts.append(frame)
        frame = deep.bytes()
    try:
        decode(frame)
        raise AssertionError("deeply nested batch decoded")
    except WireError:
        pass
    # The command encoding matches Command::wire_size exactly: rid 16 +
    # op 1 + payload_len 4 + batched 4 + count 2 + 8/key + payload bytes.
    w = Writer()
    w.cmd(cmd)
    assert len(w.bytes()) == 27 + 8 * len(cmd["keys"]) + cmd["payload_len"], len(w.bytes())
    # Client frames (tags 17–18): round-trip, truncation, and the strict
    # separation of the protocol and client planes.
    submit = {"t": "ClientSubmit", "cmd": cmd, "floor": (1 << 40) + 17}
    reply = {"t": "ClientReply", "rid": (7, 9), "response": [(1, 4), (99, 17)],
             "ts": (1 << 41) + 3}
    for f in (submit, reply):
        enc = encode_client(f)
        assert decode_client(enc) == f, f
        for cut in range(len(enc)):
            try:
                decode_client(enc[:cut])
                raise AssertionError(f"truncated client frame decoded at {cut}")
            except WireError:
                pass
        try:
            decode(enc)
            raise AssertionError("client frame decoded as a protocol message")
        except WireError:
            pass
    try:
        decode_client(encode({"t": "MStable", "dot": dot}))
        raise AssertionError("protocol message decoded as a client frame")
    except WireError:
        pass
    # ClientBusy (tag 25, the admission-control shed): minimal frame —
    # tag + rid, 17 bytes — that round-trips, truncates to WireError at
    # every cut, and stays strictly on the client plane.
    busy = {"t": "ClientBusy", "rid": (7, 9)}
    enc = encode_client(busy)
    assert enc[0] == 25 and len(enc) == 1 + 16, enc
    assert decode_client(enc) == busy
    for cut in range(len(enc)):
        try:
            decode_client(enc[:cut])
            raise AssertionError(f"truncated busy frame decoded at {cut}")
        except WireError:
            pass
    try:
        decode(enc)
        raise AssertionError("busy frame decoded as a protocol message")
    except WireError:
        pass
    b = Writer()
    b.u8(16), b.u16(1), b.u32(len(enc))
    b.parts.append(enc)
    try:
        decode(b.bytes())
        raise AssertionError("busy frame inside MBatch decoded")
    except WireError:
        pass
    try:
        decode_transfer(enc)
        raise AssertionError("busy frame decoded on the transfer plane")
    except WireError:
        pass
    # Read-flagged ClientSubmit (op tag 3, the stability-served local
    # read): exact round-trip at zero payload, truncation at every cut,
    # bit-flips never escape WireError, and the frame stays on the client
    # plane — both bare and smuggled inside an MBatch (mirrors the Rust
    # prop_read_flagged_submits_roundtrip_and_stay_on_the_client_plane).
    read_cmd = {"rid": (11, 3), "op": 3, "payload_len": 0, "batched": 0,
                "keys": [4, 17, 99]}
    read_submit = {"t": "ClientSubmit", "cmd": read_cmd, "floor": 42}
    enc = encode_client(read_submit)
    got = decode_client(enc)
    assert got == read_submit, got
    assert got["cmd"]["op"] == 3 and got["cmd"]["payload_len"] == 0
    for cut in range(len(enc)):
        try:
            decode_client(enc[:cut])
            raise AssertionError(f"truncated read submit decoded at {cut}")
        except WireError:
            pass
    for i in range(len(enc)):
        for bit in range(8):
            flipped = bytearray(enc)
            flipped[i] ^= 1 << bit
            try:
                d = decode_client(bytes(flipped))
                # A surviving decode must still be a well-formed frame —
                # flips in key/rid bytes are indistinguishable from other
                # valid values (tag 17 ^ bit 3 is tag 25, a ClientBusy);
                # what matters is: never a crash.
                assert d["t"] in ("ClientSubmit", "ClientReply", "ClientBusy")
            except WireError:
                pass
    try:
        decode(enc)
        raise AssertionError("read submit decoded as a protocol message")
    except WireError:
        pass
    b = Writer()
    b.u8(16), b.u16(1), b.u32(len(enc))
    b.parts.append(enc)
    try:
        decode(b.bytes())
        raise AssertionError("read submit inside MBatch decoded")
    except WireError:
        pass
    # An op tag past Read (4+) is malformed in both planes.
    bad_op = bytearray(enc)
    bad_op[1 + 16] = 4  # frame tag + rid(16) puts the op byte at offset 17
    try:
        decode_client(bytes(bad_op))
        raise AssertionError("op tag 4 decoded")
    except WireError:
        pass
    # An MBatch member carrying a client frame is rejected from the tag
    # peek, exactly like a nested batch.
    for member in (encode_client(submit), encode_client(reply)):
        b = Writer()
        b.u8(16), b.u16(1), b.u32(len(member))
        b.parts.append(member)
        try:
            decode(b.bytes())
            raise AssertionError("client frame inside MBatch decoded")
        except WireError:
            pass
    # Worker-routed envelope (tag 19): round-trip, truncation, and strict
    # separation from the bare-message and MBatch contexts.
    inner = {"t": "MStable", "dot": dot}
    for worker in (0, 1, 255):
        enc = encode_routed(worker, inner)
        assert enc[0] == 19
        assert decode_routed(enc) == (worker, inner)
        for cut in range(len(enc)):
            try:
                decode_routed(enc[:cut])
                raise AssertionError(f"truncated routed frame decoded at {cut}")
            except WireError:
                pass
    try:
        decode(encode_routed(0, inner))
        raise AssertionError("routed envelope decoded as a bare message")
    except WireError:
        pass
    try:
        decode_routed(encode(inner))
        raise AssertionError("bare message decoded as a routed envelope")
    except WireError:
        pass
    b = Writer()
    member = encode_routed(0, inner)
    b.u8(16), b.u16(1), b.u32(len(member))
    b.parts.append(member)
    try:
        decode(b.bytes())
        raise AssertionError("routed envelope inside MBatch decoded")
    except WireError:
        pass
    # Merged transport frame (tag 20): members are routed envelopes,
    # recovered in wire order (per-slot send order is preserved);
    # truncation, non-routed members, padding and nesting all reject.
    members = [
        (0, {"t": "MStable", "dot": dot}),
        (1, {"t": "MBatch", "msgs": [{"t": "MBump", "dot": dot, "ts": 9},
                                     {"t": "MStable", "dot": dot}]}),
        (0, {"t": "MRec", "dot": dot, "bal": 3}),
    ]
    bodies = [encode_routed(w, m) for w, m in members]
    frame = encode_merged(bodies)
    assert frame[0] == 20
    assert decode_merged(frame) == members, "merged members must round-trip in order"
    for cut in range(len(frame)):
        try:
            decode_merged(frame[:cut])
            raise AssertionError(f"truncated merged frame decoded at {cut}")
        except WireError:
            pass
    for bad_ctx in (decode, decode_routed):
        try:
            bad_ctx(frame)
            raise AssertionError("merged frame decoded outside its position")
        except WireError:
            pass
    b = Writer()
    b.u8(16), b.u16(1), b.u32(len(frame))
    b.parts.append(frame)
    try:
        decode(b.bytes())
        raise AssertionError("merged frame inside MBatch decoded")
    except WireError:
        pass
    for bad_member in (
        encode({"t": "MStable", "dot": dot}),  # bare message
        frame,  # nested merged frame
        encode_routed(0, inner) + b"\xee",  # padding inside declared length
    ):
        try:
            decode_merged(encode_merged([bad_member]))
            raise AssertionError("malformed merged member decoded")
        except WireError:
            pass
    # State-transfer plane (tags 22–24): round-trip, truncation at every
    # cut, bit-flip resilience, and strict separation from every other
    # plane — including MBatch smuggling (mirrors the Rust
    # prop_transfer_frames_roundtrip_and_stay_on_the_transfer_plane).
    manifest = {
        "t": "ManifestReply",
        "slot": 1,
        "applied": (1 << 33) + 5,
        "chunks": [0xDEAD, 0xBEEF, 0xDEAD],
        "dot_floors": [(0, 41), (2, 7)],
        "dedup": b"\x01\x02\x03\xff",
    }
    transfers = [
        {"t": "ManifestRequest", "slot": 3},
        manifest,
        {"t": "ManifestReply", "slot": 0, "applied": 0, "chunks": [],
         "dot_floors": [], "dedup": b""},
        {"t": "Chunk", "slot": 2, "hash": 0xFACE, "present": False, "data": b""},
        {"t": "Chunk", "slot": 2, "hash": 0xFACE, "present": True,
         "data": bytes(range(256)) * 2},
    ]
    for f in transfers:
        enc = encode_transfer(f)
        assert decode_transfer(enc) == f, f
        for cut in range(len(enc)):
            try:
                decode_transfer(enc[:cut])
                raise AssertionError(f"truncated transfer frame decoded at {cut}")
            except WireError:
                pass
        for ctx in (decode, decode_client):
            try:
                ctx(enc)
                raise AssertionError("transfer frame decoded on another plane")
            except WireError:
                pass
        b = Writer()
        b.u8(16), b.u16(1), b.u32(len(enc))
        b.parts.append(enc)
        try:
            decode(b.bytes())
            raise AssertionError("transfer frame inside MBatch decoded")
        except WireError:
            pass
    # Encoded size matches the Rust transfer_encoded_len arithmetic.
    enc = encode_transfer(manifest)
    assert len(enc) == 1 + 4 + 8 + 4 + 8 * 3 + 2 + 12 * 2 + 4 + 4, len(enc)
    for i in range(len(enc)):
        for bit in range(8):
            flipped = bytearray(enc)
            flipped[i] ^= 1 << bit
            try:
                d = decode_transfer(bytes(flipped))
                # Same stance as the client plane: a surviving decode is
                # a well-formed frame; what matters is never a crash.
                assert d["t"] in ("ManifestRequest", "ManifestReply", "Chunk")
            except WireError:
                pass
    # A chunk present byte other than 0/1 is malformed.
    bad = bytearray(encode_transfer(transfers[-1]))
    bad[1 + 4 + 8] = 2
    try:
        decode_transfer(bytes(bad))
        raise AssertionError("present byte 2 decoded")
    except WireError:
        pass
    # No other plane decodes as a transfer frame — protocol, epoch vote,
    # client reply, routed, merged.
    for other in (
        encode({"t": "MStable", "dot": dot}),
        encode({"t": "MEpoch", "epoch": 1, "evicted": []}),
        encode_client(reply),
        encode_routed(0, inner),
        encode_merged([encode_routed(0, inner)]),
    ):
        try:
            decode_transfer(other)
            raise AssertionError("non-transfer frame decoded as transfer")
        except WireError:
            pass
    # Incremental transport decode ≡ whole-frame decode, whatever the
    # chunking (mirrors the Rust incremental-decode property): client
    # frames wrapped in [len][from][body], fed byte-by-byte, in awkward
    # 7-byte chunks, and all at once.
    client_from = (1 << 32) - 1
    frames = [submit, reply, busy, read_submit]
    stream = b""
    for f in frames:
        body = encode_client(f)
        stream += struct.pack("<I", len(body)) + struct.pack("<I", client_from) + body

    def run_chunked(size):
        dec = FrameDecoder()
        out = []
        for off in range(0, len(stream), size):
            chunk = stream[off : off + size]
            while chunk:
                used, done = dec.feed(chunk)
                chunk = chunk[used:]
                if done:
                    assert dec.sender == client_from
                    out.append(decode_client(dec.body))
                    dec.clear()
        assert not dec.complete, "stream fully consumed but a frame pending"
        return out

    for size in (1, 7, len(stream)):
        assert run_chunked(size) == frames, f"chunk size {size} changed the frames"
    # A truncated stream waits (incomplete) instead of erroring; an
    # oversized length header errors instead of buffering.
    dec = FrameDecoder()
    rest = stream[: len(stream) - 3]
    while rest:
        used, done = dec.feed(rest)
        rest = rest[used:]
        if done:
            dec.clear()
    assert not dec.complete
    try:
        FrameDecoder().feed(struct.pack("<I", MAX_FRAME_BYTES + 1) + b"\xff" * 4)
        raise AssertionError("oversized frame header accepted")
    except WireError:
        pass


if __name__ == "__main__":
    self_check()
    print("wire codec port: self-check OK")
