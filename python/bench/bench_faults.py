"""Fault-path bench: crash -> suspect -> evict -> heal, measured via a
Python port.

Faithful port of what the nemesis PR adds (no Rust toolchain in this
container; the numbers are Python-speed but measured for real): the full
ordering path of bench_reads.py extended with the fault machinery —

1. **Healthy phase**: ops/s of the 3-replica write path, every frame
   through the real ``wire.py`` codec, fast quorums rotating across both
   peers.

2. **Degraded phase**: replica 2 crashes mid-run. Commands whose fast
   quorum targets the dead peer time out and retransmit toward the
   survivor (the port of ``Config::retry_interval_ticks``), the dead
   member's executed frontier freezes GC (per-command info records pile
   up), and the requests in flight at the crash are failed over by their
   client — re-issued at the survivor under the same rid, absorbed by
   the per-client dedup window (``Config::dedup_window``).

3. **Reconfiguration**: after the suspect delay the survivors vote the
   victim out (``MEpoch`` frames, WIRE.md tag 21) and install epoch 1.
   The GC frontier drops the evicted member and prunes the frozen
   backlog — the unfreeze the epoch subsystem exists for.

4. **Recovery phase**: the victim died holding half-driven proposals —
   dots whose ``MPropose`` reached a survivor but whose commit never
   followed. The lowest surviving member takes each one over with a
   ballot above the dead coordinator's (``MRec`` prepare, WIRE.md tag
   11), reads the recorded timestamp from the survivor's ``MRecAck``,
   and re-drives the dot to commit — the port of the ballot-based
   coordinator recovery the Rust side runs for Tempo and the dep-graph
   families.

5. **Post-eviction phase**: ops/s with quorums drawn from the survivor
   set only — the recovered throughput the gate compares against the
   healthy baseline.

Reported: per-phase ops/s, retransmits, dedup hits, MEpoch frames,
reconfiguration latency, the stalled-dot recovery (count, frames, wall
time), and the info-record footprint at the crash, at its frozen peak,
and after the unfreeze.

Run from anywhere: ``python3 python/bench/bench_faults.py``.
``--smoke`` (or ``SMOKE=1``) runs reduced iterations and leaves the
recorded BENCH_faults.json untouched (for cargo-less CI).
"""

import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import wire  # noqa: E402

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"
R, MAJORITY = 3, 2  # r=3 f=1
N_KEYS = 1_000
PHASE_OPS = 5_000 if SMOKE else 30_000
SUSPECT_AFTER_OPS = 500 if SMOKE else 3_000  # ports SimOpts.suspect_delay_us
GC_EVERY = 64  # ports Config::gc_interval_ticks
DEDUP_WINDOW = 64  # ports Config::dedup_window
PAYLOAD = 100
IN_FLIGHT_AT_CRASH = 16  # client pipeline depth failed over at the crash
STALLED_AT_CRASH = 8  # proposals the victim was coordinating mid-protocol


class Replica:
    __slots__ = ("clock", "executed_wm", "infos", "dedup", "dedup_hits", "alive")

    def __init__(self):
        self.clock = 0
        self.executed_wm = 0  # executed frontier shared via MGarbageCollect
        self.infos = {}  # seq -> per-command info record (GC prunes these)
        self.dedup = []  # most-recent executed rids (per the one client)
        self.dedup_hits = 0
        self.alive = True

    def execute(self, rid, seq):
        """Apply at this replica; absorb an in-window duplicate rid."""
        if DEDUP_WINDOW and rid in self.dedup:
            self.dedup_hits += 1
            return False
        self.dedup.append(rid)
        if len(self.dedup) > DEDUP_WINDOW:
            self.dedup.pop(0)
        # max: a recovered orphan executes after younger commands — the
        # frontier must not regress to its (older) sequence number.
        self.executed_wm = max(self.executed_wm, seq)
        return True


class Cluster:
    def __init__(self):
        self.replicas = [Replica() for _ in range(R)]
        self.epoch = 0
        self.evicted = []
        self.wire_bytes = 0
        self.retransmits = 0
        self.epoch_frames = 0
        self.seq = 0

    def group(self):
        return [i for i in range(R) if i not in self.evicted]

    def fast_peer(self, attempt_dead):
        """Rotate the non-coordinator fast-quorum slot over the current
        group; before eviction a dead peer is still drawn (and costs a
        retransmission), after eviction it cannot be."""
        peers = [p for p in self.group() if p != 0]
        return peers[attempt_dead % len(peers)]

    def write_op(self, rid):
        """One command through the ordering path; returns True when it
        needed a retransmission (its first quorum pick was dead)."""
        self.seq += 1
        seq = self.seq
        coord = self.replicas[0]
        coord.clock += 1
        key = seq % N_KEYS
        cmd = {"rid": rid, "op": 1, "payload_len": PAYLOAD, "batched": 0,
               "keys": [key]}
        dot = (0, seq)
        retried = False
        peer_id = self.fast_peer(seq)
        propose = wire.encode(
            {"t": "MPropose", "dot": dot, "cmd": cmd,
             "quorums": [(0, self.group())], "ts": [(key, coord.clock)]}
        )
        self.wire_bytes += len(propose)
        if not self.replicas[peer_id].alive:
            # Timeout toward the dead peer; re-drive at a survivor (the
            # retry_interval_ticks path).
            self.retransmits += 1
            retried = True
            peer_id = next(p for p in self.group()
                           if p != 0 and self.replicas[p].alive)
            self.wire_bytes += len(propose)
        peer = self.replicas[peer_id]
        msg = wire.decode(propose)
        proposed = msg["ts"][0][1]
        if proposed > peer.clock:
            peer.clock = proposed
        ack = wire.encode(
            {"t": "MProposeAck", "dot": dot, "ts": [(key, peer.clock)],
             "promises": [(key, ([(peer.clock, peer.clock)], []))]}
        )
        self.wire_bytes += len(ack)
        final_ts = max(coord.clock, wire.decode(ack)["ts"][0][1])
        commit = wire.encode(
            {"t": "MCommit", "dot": dot, "group": 0,
             "ts": [(key, final_ts)],
             "promises": [(0, [(key, ([(final_ts, final_ts)], []))])]}
        )
        for p in self.group():
            if p == 0:
                continue
            self.wire_bytes += len(commit)
            if self.replicas[p].alive:
                wire.decode(commit)
        # Execute at every live group member; each keeps the command's
        # info record until the GC exchange proves group-wide execution.
        for p in self.group():
            rep = self.replicas[p]
            if rep.alive:
                rep.infos[seq] = (dot, final_ts)
                rep.execute(rid, seq)
        if seq % GC_EVERY == 0:
            self.gc_exchange()
        return retried

    def gc_exchange(self):
        """Port of MGarbageCollect: share executed frontiers across the
        current group and prune infos up to the minimum. A crashed
        member's frozen frontier pins the minimum until it is evicted."""
        frames = [
            wire.encode({"t": "MGarbageCollect",
                         "executed": [(p, self.replicas[p].executed_wm)]})
            for p in self.group()
        ]
        for f in frames:
            self.wire_bytes += len(f) * (len(self.group()) - 1)
            wire.decode(f)
        frontier = min(self.replicas[p].executed_wm for p in self.group())
        for p in self.group():
            rep = self.replicas[p]
            if rep.alive:
                rep.infos = {s: i for s, i in rep.infos.items() if s > frontier}

    def stall_victim_coordinations(self, n, rid_base):
        """Replica 2 coordinates ``n`` commands that die mid-propose: the
        MPropose reaches survivor replica 1 (which bumps its clock and
        records the promised timestamp), but the coordinator crashes
        before driving the commit. Returns the stalled records the
        survivors hold — kept out of ``Replica.infos`` on purpose, so
        the GC-footprint numbers stay about committed commands only."""
        survivor = self.replicas[1]
        stalled = []
        for i in range(n):
            self.seq += 1
            seq = self.seq
            victim = self.replicas[2]
            victim.clock += 1
            key = seq % N_KEYS
            dot = (2, seq)
            rid = (2, rid_base + i)
            cmd = {"rid": rid, "op": 1, "payload_len": PAYLOAD, "batched": 0,
                   "keys": [key]}
            propose = wire.encode(
                {"t": "MPropose", "dot": dot, "cmd": cmd,
                 "quorums": [(0, self.group())], "ts": [(key, victim.clock)]}
            )
            self.wire_bytes += len(propose)
            msg = wire.decode(propose)
            proposed = msg["ts"][0][1]
            if proposed > survivor.clock:
                survivor.clock = proposed
            # The survivor's ack heads back toward a coordinator that is
            # about to die; the commit never follows.
            ack = wire.encode(
                {"t": "MProposeAck", "dot": dot,
                 "ts": [(key, survivor.clock)],
                 "promises": [(key, ([(survivor.clock, survivor.clock)],
                                     []))]}
            )
            self.wire_bytes += len(ack)
            stalled.append((dot, key, survivor.clock, rid, seq))
        return stalled

    def recover_stalled(self, stalled):
        """Ballot-based coordinator takeover for the victim's stalled
        dots — the port of what ``MRecDep``/``MRec`` does in Rust. The
        lowest surviving member prepares each dot with an owned ballot
        above the dead coordinator's initial one (``ballot::next_owned``
        steps by r, so ``initial(victim) + R`` lands back on replica 0),
        reads the recorded timestamp from the survivor's MRecAck, and
        re-drives the dot to commit at the survivor set. Returns
        (recovered_count, rec_frames)."""
        new_coord = self.replicas[min(self.group())]
        survivor_id = next(p for p in self.group() if p != min(self.group()))
        survivor = self.replicas[survivor_id]
        rec_frames = 0
        recovered = 0
        victim_initial_bal = 2 + 1  # initial coordinator ballots are 1..=r
        takeover_bal = victim_initial_bal + R
        for dot, key, ts, rid, seq in stalled:
            prepare = wire.encode(
                {"t": "MRec", "dot": dot, "bal": takeover_bal})
            self.wire_bytes += len(prepare)
            rec_frames += 1
            assert wire.decode(prepare)["bal"] > victim_initial_bal
            # The survivor saw the payload and promised a timestamp: it
            # answers from the Propose phase with what it recorded.
            rec_ack = wire.encode(
                {"t": "MRecAck", "dot": dot, "ts": [(key, ts)],
                 "phase": "Propose", "abal": victim_initial_bal,
                 "bal": takeover_bal}
            )
            self.wire_bytes += len(rec_ack)
            rec_frames += 1
            ack = wire.decode(rec_ack)
            final_ts = max(ack["ts"][0][1], new_coord.clock)
            commit = wire.encode(
                {"t": "MCommit", "dot": dot, "group": 0,
                 "ts": [(key, final_ts)],
                 "promises": [(0, [(key, ([(final_ts, final_ts)], []))])]}
            )
            for p in self.group():
                if p == min(self.group()):
                    continue
                self.wire_bytes += len(commit)
                wire.decode(commit)
            for p in self.group():
                rep = self.replicas[p]
                rep.infos[seq] = (dot, final_ts)
                rep.execute(rid, seq)
            recovered += 1
        assert survivor.executed_wm >= max(s[4] for s in stalled)
        return recovered, rec_frames

    def evict(self, victim):
        """Survivor vote: every live member broadcasts its MEpoch vote
        for (epoch+1, evicted+victim); a majority installs it."""
        proposal = {"t": "MEpoch", "epoch": self.epoch + 1,
                    "evicted": sorted(self.evicted + [victim])}
        votes = 0
        for p in self.group():
            if not self.replicas[p].alive:
                continue
            frame = wire.encode(proposal)
            self.epoch_frames += 1
            self.wire_bytes += len(frame) * (len(self.group()) - 1)
            decoded = wire.decode(frame)
            assert decoded == proposal
            votes += 1
        assert votes >= MAJORITY, "survivors cannot form an epoch majority"
        self.epoch = proposal["epoch"]
        self.evicted = proposal["evicted"]


def run_phase(cluster, ops, rid_base):
    start = time.perf_counter()
    retried = 0
    for i in range(ops):
        if cluster.write_op((1, rid_base + i)):
            retried += 1
    elapsed = time.perf_counter() - start
    return {"ops": ops, "ops_per_s_wall": round(ops / elapsed)}, retried


def main():
    cluster = Cluster()

    healthy, _ = run_phase(cluster, PHASE_OPS, 0)
    print(f"healthy       : {healthy['ops_per_s_wall']:>9} ops/s "
          f"({R} replicas, quorums over both peers)")

    # Replica 2 starts coordinating its own commands and dies holding
    # them mid-propose: the survivors have promised timestamps but no
    # commit, and only the ballot takeover after eviction can finish
    # them.
    stalled = cluster.stall_victim_coordinations(STALLED_AT_CRASH,
                                                 9_000_000)

    # Crash replica 2. The client had IN_FLIGHT_AT_CRASH requests
    # pipelined through it; it fails over and re-issues them at the
    # survivor coordinator under their original rids. The cluster
    # already executed them (their commits landed before the crash), so
    # the dedup window must absorb every copy.
    crash_wall = time.perf_counter()
    cluster.replicas[2].alive = False
    infos_at_crash = len(cluster.replicas[0].infos)
    for i in range(IN_FLIGHT_AT_CRASH):
        cluster.write_op((1, cluster.seq - 1 - i))  # re-issue, same rid
    dedup_hits = sum(r.dedup_hits for r in cluster.replicas if r.alive)
    assert dedup_hits >= IN_FLIGHT_AT_CRASH, (
        f"failover re-issues not absorbed: {dedup_hits}"
    )

    # Degraded window until the failure detector fires: dead-peer quorum
    # picks retransmit, and the frozen frontier pins GC.
    retrans0 = cluster.retransmits
    degraded, _ = run_phase(cluster, SUSPECT_AFTER_OPS, PHASE_OPS + 100)
    degraded["retransmits"] = cluster.retransmits - retrans0
    infos_peak_frozen = len(cluster.replicas[0].infos)
    print(f"degraded      : {degraded['ops_per_s_wall']:>9} ops/s "
          f"({degraded['retransmits']} retransmits, "
          f"{infos_peak_frozen} info records frozen, "
          f"{dedup_hits} failover re-issues absorbed)")

    # Suspect -> evict: survivors vote replica 2 into epoch 1, the GC
    # frontier drops it, and the frozen backlog prunes.
    cluster.evict(2)
    cluster.gc_exchange()
    reconfigure_ms = (time.perf_counter() - crash_wall) * 1e3
    infos_after_unfreeze = len(cluster.replicas[0].infos)
    assert cluster.epoch == 1 and cluster.evicted == [2]
    assert infos_after_unfreeze < infos_peak_frozen, (
        f"eviction did not unfreeze GC: {infos_peak_frozen} -> "
        f"{infos_after_unfreeze}"
    )
    print(f"reconfigure   : epoch {cluster.epoch} evicting {cluster.evicted} "
          f"after {reconfigure_ms:.1f} ms wall "
          f"({cluster.epoch_frames} MEpoch frames); "
          f"info records {infos_peak_frozen} -> {infos_after_unfreeze}")

    # The victim's stalled dots: the lowest survivor takes each one over
    # with a ballot above the dead coordinator's and re-drives it to
    # commit from the survivors' recorded timestamps.
    recover_wall = time.perf_counter()
    recovered, rec_frames = cluster.recover_stalled(stalled)
    recover_ms = (time.perf_counter() - recover_wall) * 1e3
    assert recovered == STALLED_AT_CRASH, (
        f"stalled dots left uncommitted: {recovered}/{STALLED_AT_CRASH}"
    )
    print(f"recovery      : {recovered}/{STALLED_AT_CRASH} stalled dots "
          f"re-driven to commit ({rec_frames} MRec/MRecAck frames, "
          f"{recover_ms:.2f} ms wall)")

    post, post_retried = run_phase(cluster, PHASE_OPS, 2 * PHASE_OPS + 100)
    assert post_retried == 0, "post-eviction quorums must avoid the victim"
    print(f"post-eviction : {post['ops_per_s_wall']:>9} ops/s "
          f"(quorums over the survivor set)")

    result = {
        "bench": "faults",
        "harness": "python port (python/bench/bench_faults.py); no Rust "
        "toolchain in this container — numbers are Python-speed but "
        "measured for real: the bench_reads.py ordering path with every "
        "frame through the wire.py codec, extended with crash, "
        "retransmission, client failover + dedup, the MEpoch eviction "
        "vote, and frontier GC. The Rust nemesis harness "
        "(rust/tests/nemesis.rs) asserts the same machinery under the "
        "deterministic simulator",
        "workload": f"single-key writes over {N_KEYS} keys, {PHASE_OPS} ops "
        f"per steady phase, crash of replica 2 with "
        f"{IN_FLIGHT_AT_CRASH} requests failed over and "
        f"{STALLED_AT_CRASH} of its own proposals stalled mid-protocol, "
        f"suspect after {SUSPECT_AFTER_OPS} ops, r={R} "
        f"majority={MAJORITY}",
        "phases": [
            {"phase": "healthy", **healthy},
            {"phase": "degraded", **degraded},
            {"phase": "post_eviction", **post},
        ],
        "recovery": {
            "suspect_after_ops": SUSPECT_AFTER_OPS,
            "epoch_installed": cluster.epoch,
            "evicted": cluster.evicted,
            "epoch_frames": cluster.epoch_frames,
            "time_to_reconfigure_ms": round(reconfigure_ms, 1),
            "failover_reissues": IN_FLIGHT_AT_CRASH,
            "dedup_hits": dedup_hits,
            "gc_info_records": {
                "at_crash": infos_at_crash,
                "peak_frozen": infos_peak_frozen,
                "after_unfreeze": infos_after_unfreeze,
            },
            "stalled_dots": {
                "stalled": STALLED_AT_CRASH,
                "recovered_to_commit": recovered,
                "rec_frames": rec_frames,
                "time_to_recover_ms": round(recover_ms, 2),
            },
        },
        "wire_bytes_total": cluster.wire_bytes,
        "regenerate": "python3 python/bench/bench_faults.py",
    }
    if SMOKE:
        print(json.dumps(result, indent=2))
        print("smoke mode: BENCH_faults.json left untouched")
        return
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(os.path.join(root, "BENCH_faults.json"))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"written to {path}")


if __name__ == "__main__":
    main()
