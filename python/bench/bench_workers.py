"""Worker-sharding hot path, measured via a Python port.

Faithful port of the two mechanisms PR 4 adds, measured for real on this
machine (no Rust toolchain in this container; ``cargo bench --bench
workers`` overwrites BENCH_workers.json with the Rust simulator numbers):

1. **Router data path** (rust/src/protocol/common/shard.rs): per command,
   hash the key to a worker slot (SplitMix64, ported bit-for-bit), then
   run the per-key protocol hot loop — clock bump, per-source promise
   frontier update, majority-watermark query, execution-queue advance —
   against that slot's shared-nothing state. Reported per (workers, θ)
   cell: single-thread ops/s (the router must not tax the hot path) and
   measured allocations/op (``sys.getallocatedblocks`` delta).
   Shared-nothing slots scale across cores by construction; this harness
   is single-threaded, so it reports per-slot cost, not thread speedup.

2. **Zero-clone fan-out** (Arc-backed ``Command``): building one command
   and "broadcasting" it to r-1 = 4 peers by sharing one immutable buffer
   vs deep-copying the key/payload buffers per peer — allocation counts
   measured the same way.

Run from anywhere: ``python3 python/bench/bench_workers.py``.
``--smoke`` (or ``SMOKE=1``) runs reduced iterations and leaves the
recorded BENCH_workers.json untouched (for cargo-less CI).
"""

import json
import os
import random
import sys
import time

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"
R, MAJORITY = 5, 3
N_KEYS = 10_000
OPS = 30_000 if SMOKE else 200_000
MASK = (1 << 64) - 1


def splitmix(key):
    """SplitMix64 finalizer, ported bit-for-bit from shard.rs."""
    z = (key + 0x9E3779B97F4A7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return z ^ (z >> 31)


def worker_of_key(key, workers):
    """Port of shard::worker_of_key."""
    if workers <= 1:
        return 0
    return splitmix(key) % workers


def zipf_keys(theta, n_ops, seed):
    """Pre-drawn zipf(theta) key stream over N_KEYS keys."""
    rng = random.Random(seed)
    if theta == 0.0:
        return [rng.randrange(N_KEYS) for _ in range(n_ops)]
    weights = [1.0 / ((i + 1) ** theta) for i in range(N_KEYS)]
    total = sum(weights)
    cdf, acc = [], 0.0
    for w in weights:
        acc += w / total
        cdf.append(acc)
    import bisect

    return [bisect.bisect_left(cdf, rng.random()) for _ in range(n_ops)]


class KeyState:
    """Per-key protocol state: clock, per-source promise frontiers with
    the majority watermark, and the (ts-ordered) execution queue."""

    __slots__ = ("clock", "frontiers", "watermark", "queue")

    def __init__(self):
        self.clock = 0
        self.frontiers = [0] * R
        self.watermark = 0
        self.queue = []

    def step(self, src):
        # proposal + detached promise from `src` + stability advance —
        # the tempo per-key hot loop in miniature.
        self.clock += 1
        ts = self.clock
        self.frontiers[src] = ts
        w = sorted(self.frontiers)[R - MAJORITY]
        if w > self.watermark:
            self.watermark = w
        self.queue.append(ts)
        executed = 0
        while self.queue and self.queue[0] <= self.watermark:
            self.queue.pop(0)
            executed += 1
        return executed


def bench_cell(workers, theta):
    # The hash is computed for every cell (the 1-worker router hashes
    # too in spirit), so cells differ only in how the state is
    # partitioned — the thing being measured.
    keys = zipf_keys(theta, OPS, seed=7)
    slots = [dict() for _ in range(workers)]
    blocks0 = sys.getallocatedblocks()
    start = time.perf_counter()
    executed = 0
    for i, k in enumerate(keys):
        w = splitmix(k) % workers
        state = slots[w].get(k)
        if state is None:
            state = slots[w][k] = KeyState()
        executed += state.step(i % R)
    el = time.perf_counter() - start
    retained = max(0, sys.getallocatedblocks() - blocks0)
    return {
        "workers": workers,
        "zipf_theta": theta,
        "contention": "low" if theta < 0.9 else "high",
        "ops": OPS,
        "executed": executed,
        "ops_per_s_single_thread": round(OPS / el),
        # Python cannot count cumulative heap allocations without C
        # hooks; this is the *net retained* blocks per op — ~0 means the
        # hot loop holds per-key state only, nothing per op. The Rust
        # bench's counting allocator records true allocations/op and
        # overwrites this file.
        "allocs_per_op": round(retained / OPS, 3),
        "allocs_per_op_semantics": "net retained blocks/op (python port)",
    }


def bench_fanout():
    """Deep-copy vs shared fan-out of one command to r-1 peers: the loops
    construct exactly `2 * peers` fresh buffers per command (key list +
    payload copy per peer) vs 1 shared tuple; the measured quantity is the
    wall time that buffer churn costs per command."""
    peers = R - 1
    n = OPS // 10
    keys = list(range(2))
    payload = bytes(100)

    start = time.perf_counter()
    sink = 0
    for _ in range(n):
        # Seed behaviour: one fresh key buffer + payload copy per peer.
        msgs = [(list(keys), bytes(payload)) for _ in range(peers)]
        sink += len(msgs)
    deep_el = time.perf_counter() - start

    start = time.perf_counter()
    for _ in range(n):
        # Arc behaviour: build once, share the same immutable buffers.
        cmd = (tuple(keys), payload)
        msgs = [cmd for _ in range(peers)]
        sink += len(msgs)
    shared_el = time.perf_counter() - start

    return {
        "peers": peers,
        "commands": n,
        "deep_copy_buffers_per_cmd": 2 * peers,
        "shared_buffers_per_cmd": 1,
        "deep_copy_ns_per_cmd": round(deep_el / n * 1e9, 1),
        "shared_ns_per_cmd": round(shared_el / n * 1e9, 1),
        "_sink": sink and 0,
    }


def main():
    cells = []
    for theta in (0.5, 0.99):
        for workers in (1, 2, 4):
            c = bench_cell(workers, theta)
            print(
                f"theta={theta:<4} workers={workers}: "
                f"{c['ops_per_s_single_thread']:>9} ops/s single-thread, "
                f"{c['allocs_per_op']:>6} allocs/op"
            )
            cells.append(c)
    fanout = bench_fanout()
    fanout.pop("_sink", None)
    print(
        f"fan-out to {fanout['peers']} peers: "
        f"{fanout['deep_copy_buffers_per_cmd']} buffers/cmd "
        f"({fanout['deep_copy_ns_per_cmd']} ns) deep-copied vs "
        f"{fanout['shared_buffers_per_cmd']} shared "
        f"({fanout['shared_ns_per_cmd']} ns)"
    )
    result = {
        "bench": "worker_sharding",
        "harness": "python port (python/bench/bench_workers.py); no Rust "
        "toolchain in this container — numbers are Python-speed but "
        "measured for real: router+per-key hot loop ops/s and "
        "sys.getallocatedblocks allocations. Single-threaded: shows the "
        "router does not tax the hot path; thread scaling comes from the "
        "shared-nothing slots (one thread per slot in net::start_node). "
        "`cargo bench --bench workers` overwrites this file with the Rust "
        "simulator numbers",
        "workload": f"single-key zipf over {N_KEYS} keys, {OPS} ops per "
        f"cell, r={R} majority={MAJORITY}",
        "cells": cells,
        "fanout": fanout,
        "regenerate": "cargo bench --bench workers",
    }
    if SMOKE:
        print(json.dumps(result, indent=2))
        print("smoke mode: BENCH_workers.json left untouched")
        return
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(os.path.join(root, "BENCH_workers.json"))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"written to {path}")


if __name__ == "__main__":
    main()
