"""Batched vs unbatched framing, measured through the wire-codec port.

The container this repo grows in has no Rust toolchain, so the
authoritative simulator comparison (``cargo bench --bench microbench``,
which overwrites BENCH_batching.json with throughput numbers from the
CPU/NIC resource model) cannot run here. This script measures what *can*
be measured for real on this machine: for a realistic mix of protocol
messages bound for one peer, the frames, bytes and encode+decode time of
one-frame-per-message vs ``MBatch`` coalescing (docs/WIRE.md tag 16),
including the runtime's 8-byte per-frame header (len + sender).

Run from anywhere: ``python3 python/bench/bench_batching.py``.
``--smoke`` (or ``SMOKE=1``) runs a fast regression pass — the codec
round-trip and batching equivalence checks at reduced iteration counts —
without overwriting the recorded BENCH_batching.json (for cargo-less CI).
"""

import json
import os
import sys
import time

from wire import decode, encode

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"

FRAME_HDR = 8  # u32 len + u32 sender, net/mod.rs write_frame
BATCH_MAX = 16  # Config::batch_max_msgs used in the comparison


def message_mix(n):
    """A tick interval's worth of traffic to one peer: proposals and acks
    for distinct commands plus the periodic promise/GC exchange."""
    out = []
    for i in range(n):
        dot = (i % 5, 1 + i)
        cmd = {
            "rid": (i, 1 + i),
            "op": 1,
            "payload_len": 100,
            "batched": 1,
            "keys": [i % 3],
        }
        kind = i % 6
        if kind == 0:
            out.append(
                {
                    "t": "MPropose",
                    "dot": dot,
                    "cmd": cmd,
                    "quorums": [(0, [0, 1, 2])],
                    "ts": [(i % 3, 10 + i)],
                }
            )
        elif kind == 1:
            ps = ([(1, 5 + i)], [(dot, 10 + i)])
            out.append(
                {"t": "MProposeAck", "dot": dot, "ts": [(i % 3, 10 + i)], "promises": [(i % 3, ps)]}
            )
        elif kind == 2:
            out.append(
                {"t": "MCommit", "dot": dot, "group": 0, "ts": [(i % 3, 10 + i)], "promises": []}
            )
        elif kind == 3:
            out.append({"t": "MPromises", "promises": [(i % 3, ([(1, 20 + i)], []))]})
        elif kind == 4:
            out.append({"t": "MGarbageCollect", "executed": [(j, 50 + i) for j in range(5)]})
        else:
            out.append({"t": "MStable", "dot": dot})
    return out


def batches(msgs, size):
    for i in range(0, len(msgs), size):
        chunk = msgs[i : i + size]
        yield chunk[0] if len(chunk) == 1 else {"t": "MBatch", "msgs": chunk}


def measure(frames, rounds):
    """Encode+decode wall time over `rounds` passes; returns (s, bytes, n)."""
    wire_bytes = sum(len(encode(f)) + FRAME_HDR for f in frames)
    start = time.perf_counter()
    for _ in range(rounds):
        for f in frames:
            decode(encode(f))
    return time.perf_counter() - start, wire_bytes, len(frames)


def main():
    n_msgs, rounds = (192, 3) if SMOKE else (960, 30)
    msgs = message_mix(n_msgs)
    flat = [decode(encode(b)) for b in batches(msgs, BATCH_MAX)]
    assert [m for b in flat for m in (b["msgs"] if b["t"] == "MBatch" else [b])] == msgs

    unb_s, unb_bytes, unb_frames = measure(msgs, rounds)
    bat_s, bat_bytes, bat_frames = measure(list(batches(msgs, BATCH_MAX)), rounds)

    total = n_msgs * rounds
    result = {
        "bench": "message_batching",
        "harness": "python wire-codec port (python/bench/wire.py); no Rust "
        "toolchain in this container — `cargo bench --bench microbench` "
        "overwrites this file with the simulator comparison under the "
        "CPU/NIC resource model",
        "workload": f"{n_msgs}-message mix (propose/ack/commit/promises/gc/stable) "
        f"to one peer, batch_max_msgs={BATCH_MAX}, 8B frame header",
        "unbatched_frames": unb_frames,
        "batched_frames": bat_frames,
        "frame_reduction": round(unb_frames / bat_frames, 2),
        "unbatched_wire_bytes": unb_bytes,
        "batched_wire_bytes": bat_bytes,
        "unbatched_us_per_msg": round(unb_s / total * 1e6, 3),
        "batched_us_per_msg": round(bat_s / total * 1e6, 3),
        "codec_speedup": round(unb_s / bat_s, 2),
        "regenerate": "python3 python/bench/bench_batching.py "
        "(or cargo bench --bench microbench for the simulator numbers)",
    }
    if SMOKE:
        print(json.dumps(result, indent=2))
        print("smoke mode: BENCH_batching.json left untouched")
        return
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(os.path.join(root, "BENCH_batching.json"))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"written to {path}")


if __name__ == "__main__":
    main()
