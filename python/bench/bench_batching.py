"""Batched vs unbatched framing, measured through the wire-codec port
AND over a real localhost TCP socket pair.

The container this repo grows in has no Rust toolchain, so the
authoritative simulator comparison (``cargo bench --bench microbench``,
which overwrites BENCH_batching.json with throughput numbers from the
CPU/NIC resource model) cannot run here. This script measures what *can*
be measured for real on this machine, and records both:

- **codec section**: for a realistic mix of protocol messages bound for
  one peer, the frames, bytes and encode+decode time of
  one-frame-per-message vs ``MBatch`` coalescing (docs/WIRE.md tag 16),
  including the runtime's 8-byte per-frame header (len + sender). Pure
  CPU: batching is allowed to be a slight *loss* here — the tag-16
  envelope is extra bytes and the codec work is the same.
- **tcp section**: the same frame streams pumped through a real
  ``AF_INET`` loopback connection with ``TCP_NODELAY``, one ``send(2)``
  per frame and framing-level accounting on the receiver — the shape of
  the runtime's write path (net/mod.rs writes one frame per queued
  message unless the batcher coalesced them). This is where batching
  must win: 16× fewer syscalls and frames for the same payload. The CI
  gate (check_bench.py) holds batched ≥ unbatched over TCP.

Run from anywhere: ``python3 python/bench/bench_batching.py``.
``--smoke`` (or ``SMOKE=1``) runs a fast regression pass — the codec
round-trip, batching equivalence and TCP comparison at reduced iteration
counts — without overwriting the recorded BENCH_batching.json (for
cargo-less CI).
"""

import json
import os
import socket
import struct
import sys
import threading
import time

from wire import decode, encode

SMOKE = "--smoke" in sys.argv[1:] or os.environ.get("SMOKE") == "1"

FRAME_HDR = 8  # u32 len + u32 sender, net/mod.rs write_frame
BATCH_MAX = 16  # Config::batch_max_msgs used in the comparison


def message_mix(n):
    """A tick interval's worth of traffic to one peer: proposals and acks
    for distinct commands plus the periodic promise/GC exchange."""
    out = []
    for i in range(n):
        dot = (i % 5, 1 + i)
        cmd = {
            "rid": (i, 1 + i),
            "op": 1,
            "payload_len": 100,
            "batched": 1,
            "keys": [i % 3],
        }
        kind = i % 6
        if kind == 0:
            out.append(
                {
                    "t": "MPropose",
                    "dot": dot,
                    "cmd": cmd,
                    "quorums": [(0, [0, 1, 2])],
                    "ts": [(i % 3, 10 + i)],
                }
            )
        elif kind == 1:
            ps = ([(1, 5 + i)], [(dot, 10 + i)])
            out.append(
                {"t": "MProposeAck", "dot": dot, "ts": [(i % 3, 10 + i)], "promises": [(i % 3, ps)]}
            )
        elif kind == 2:
            out.append(
                {"t": "MCommit", "dot": dot, "group": 0, "ts": [(i % 3, 10 + i)], "promises": []}
            )
        elif kind == 3:
            out.append({"t": "MPromises", "promises": [(i % 3, ([(1, 20 + i)], []))]})
        elif kind == 4:
            out.append({"t": "MGarbageCollect", "executed": [(j, 50 + i) for j in range(5)]})
        else:
            out.append({"t": "MStable", "dot": dot})
    return out


def batches(msgs, size):
    for i in range(0, len(msgs), size):
        chunk = msgs[i : i + size]
        yield chunk[0] if len(chunk) == 1 else {"t": "MBatch", "msgs": chunk}


def measure(frames, rounds):
    """Encode+decode wall time over `rounds` passes; returns (s, bytes, n)."""
    wire_bytes = sum(len(encode(f)) + FRAME_HDR for f in frames)
    start = time.perf_counter()
    for _ in range(rounds):
        for f in frames:
            decode(encode(f))
    return time.perf_counter() - start, wire_bytes, len(frames)


def tcp_sink(listener, n_msgs, rounds, ready):
    """Accept one connection and, per round, read frames until `n_msgs`
    messages arrived, then ack with one byte (the round barrier the
    closed-loop client waits on). Accounting is framing-level only — the
    tag byte, plus the member count for an ``MBatch`` (tag 16, ``u16``
    count) — because this cell isolates the *transport*: the codec
    section above already measures the full decode, where Python's
    per-byte overhead would swamp the syscall savings being compared."""
    ready.set()
    conn, _ = listener.accept()
    with conn:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        for _ in range(rounds):
            seen = 0
            while seen < n_msgs:
                hdr = b""
                while len(hdr) < FRAME_HDR:
                    chunk = conn.recv(FRAME_HDR - len(hdr))
                    assert chunk, "peer closed mid-header"
                    hdr += chunk
                (length, _sender) = struct.unpack("<II", hdr)
                body = b""
                while len(body) < length:
                    chunk = conn.recv(length - len(body))
                    assert chunk, "peer closed mid-body"
                    body += chunk
                if body[0] == 16:  # MBatch: u16 member count after the tag
                    (members,) = struct.unpack_from("<H", body, 1)
                    seen += members
                else:
                    seen += 1
            conn.sendall(b"\x01")


def tcp_cell(frames, n_msgs, rounds):
    """Pump pre-encoded frames through a loopback TCP connection, one
    send(2) per frame (the unbatched runtime's write shape), and wait for
    the sink's ack each round. Returns messages/s of wall time."""
    wire = [struct.pack("<II", len(b), 0) + b for b in (encode(f) for f in frames)]
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind(("127.0.0.1", 0))
    listener.listen(1)
    ready = threading.Event()
    sink = threading.Thread(target=tcp_sink, args=(listener, n_msgs, rounds, ready), daemon=True)
    sink.start()
    ready.wait()
    conn = socket.create_connection(listener.getsockname())
    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    start = time.perf_counter()
    with conn:
        for _ in range(rounds):
            for frame in wire:
                conn.sendall(frame)
            assert conn.recv(1) == b"\x01", "sink did not ack the round"
    elapsed = time.perf_counter() - start
    sink.join(timeout=10)
    listener.close()
    return n_msgs * rounds / elapsed


def main():
    n_msgs, rounds = (192, 3) if SMOKE else (960, 30)
    msgs = message_mix(n_msgs)
    flat = [decode(encode(b)) for b in batches(msgs, BATCH_MAX)]
    assert [m for b in flat for m in (b["msgs"] if b["t"] == "MBatch" else [b])] == msgs

    unb_s, unb_bytes, unb_frames = measure(msgs, rounds)
    bat_s, bat_bytes, bat_frames = measure(list(batches(msgs, BATCH_MAX)), rounds)

    tcp_rounds = rounds if SMOKE else rounds * 2
    tcp_unb = tcp_cell(msgs, n_msgs, tcp_rounds)
    tcp_bat = tcp_cell(list(batches(msgs, BATCH_MAX)), n_msgs, tcp_rounds)

    total = n_msgs * rounds
    result = {
        "bench": "message_batching",
        "harness": "python wire-codec port (python/bench/wire.py); no Rust "
        "toolchain in this container — `cargo bench --bench microbench` "
        "overwrites this file with the simulator comparison under the "
        "CPU/NIC resource model",
        "workload": f"{n_msgs}-message mix (propose/ack/commit/promises/gc/stable) "
        f"to one peer, batch_max_msgs={BATCH_MAX}, 8B frame header",
        "unbatched_frames": unb_frames,
        "batched_frames": bat_frames,
        "frame_reduction": round(unb_frames / bat_frames, 2),
        "unbatched_wire_bytes": unb_bytes,
        "batched_wire_bytes": bat_bytes,
        "unbatched_us_per_msg": round(unb_s / total * 1e6, 3),
        "batched_us_per_msg": round(bat_s / total * 1e6, 3),
        "codec_speedup": round(unb_s / bat_s, 2),
        "tcp": {
            "transport": "real 127.0.0.1 socket pair, TCP_NODELAY, one send(2) "
            "per frame, receiver counts framed messages and acks each round",
            "rounds": tcp_rounds,
            "unbatched_msgs_per_s": round(tcp_unb),
            "batched_msgs_per_s": round(tcp_bat),
            "tcp_speedup": round(tcp_bat / tcp_unb, 2),
        },
        "regenerate": "python3 python/bench/bench_batching.py "
        "(or cargo bench --bench microbench for the simulator numbers)",
    }
    if SMOKE:
        print(json.dumps(result, indent=2))
        print("smoke mode: BENCH_batching.json left untouched")
        return
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(os.path.join(root, "BENCH_batching.json"))
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"written to {path}")


if __name__ == "__main__":
    main()
